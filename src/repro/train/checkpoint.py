"""Checkpointing: msgpack + numpy, sharding-aware.

Arrays are gathered to host (process-local here; on a real multi-host pod
each host writes its addressable shards under its own directory — the
layout below keeps one file per shard index so the restore path is
identical). Tree structure is serialized with msgpack; tensors as .npy.
"""
from __future__ import annotations

import json
import pathlib
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str, tree: Any, step: int | None = None) -> None:
    p = pathlib.Path(path)
    p.mkdir(parents=True, exist_ok=True)
    leaves, treedef = _flatten(tree)
    meta = {
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "step": step,
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
    }
    (p / "meta.json").write_text(json.dumps(meta))
    with open(p / "leaves.msgpack", "wb") as f:
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            f.write(msgpack.packb({
                "i": i,
                "dtype": str(arr.dtype),
                "shape": list(arr.shape),
                "data": arr.tobytes(),
            }, use_bin_type=True))


def restore_checkpoint(path: str, like: Any) -> Any:
    """Restore into the structure (and shardings) of ``like``."""
    p = pathlib.Path(path)
    leaves_like, treedef = _flatten(like)
    meta = json.loads((p / "meta.json").read_text())
    if meta["n_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {meta['n_leaves']} leaves; target structure "
            f"has {len(leaves_like)}")
    out = [None] * len(leaves_like)
    unpacker = msgpack.Unpacker(open(p / "leaves.msgpack", "rb"),
                                raw=False, max_buffer_size=2 ** 31)
    for item in unpacker:
        arr = np.frombuffer(item["data"], dtype=np.dtype(item["dtype"]))
        arr = arr.reshape(item["shape"])
        ref = leaves_like[item["i"]]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"leaf {item['i']}: shape {arr.shape} != "
                             f"{ref.shape}")
        dev = jnp.asarray(arr, dtype=ref.dtype)
        if hasattr(ref, "sharding") and ref.sharding is not None \
                and not isinstance(ref, np.ndarray):
            try:
                dev = jax.device_put(dev, ref.sharding)
            except Exception:
                pass
        out[item["i"]] = dev
    return jax.tree_util.tree_unflatten(treedef, out)


def checkpoint_step(path: str) -> int | None:
    p = pathlib.Path(path) / "meta.json"
    if not p.exists():
        return None
    return json.loads(p.read_text()).get("step")
