"""Training substrate: AdamW, loss, trainer, checkpointing."""
from .checkpoint import checkpoint_step, restore_checkpoint, save_checkpoint
from .loss import next_token_loss
from .optimizer import (AdamWConfig, OptState, adamw_update, global_norm,
                        init_opt_state, lr_schedule)
from .trainer import TrainState, init_train_state, make_train_step

__all__ = ["AdamWConfig", "OptState", "adamw_update", "init_opt_state",
           "lr_schedule", "global_norm", "next_token_loss", "TrainState",
           "make_train_step", "init_train_state", "save_checkpoint",
           "restore_checkpoint", "checkpoint_step"]
