"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy: on TPU the Pallas kernel runs compiled; on CPU (this
container, and any unit-test environment) it runs in interpret mode, which
executes the same kernel body in Python for correctness. ``force_ref=True``
bypasses Pallas entirely (used by the dry-run so the XLA cost model sees
analyzable HLO instead of an opaque custom call).

Model-facing adapters translate between model layouts ([B, S, nh, hd]) and
kernel layouts ([Bkv, G, S, hd] etc.).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import decode_attention as _decode_pallas
from .decode_attention import paged_decode_attention as _paged_pallas
from .flash_attention import flash_attention as _flash_pallas
from .fused_ffn import fused_ffn as _ffn_pallas
from .rwkv6_scan import rwkv6_scan as _rwkv_pallas
from .ssd_scan import ssd_scan as _ssd_pallas

Array = jnp.ndarray


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ------------------------------------------------------------------ attention
def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int | None = None,
                    force_ref: bool = False) -> Array:
    """Model layout: q [B,S,nh,hd]; k,v [B,S,nkv,hd] -> [B,S,nh,hd]."""
    B, S, nh, hd = q.shape
    nkv = k.shape[2]
    G = nh // nkv
    qk = q.reshape(B, S, nkv, G, hd).transpose(0, 2, 3, 1, 4) \
        .reshape(B * nkv, G, S, hd)
    kk = k.transpose(0, 2, 1, 3).reshape(B * nkv, S, hd)
    vv = v.transpose(0, 2, 1, 3).reshape(B * nkv, S, hd)
    if force_ref:
        qf = qk.reshape(B * nkv * G, S, hd)
        kf = jnp.repeat(kk[:, None], G, 1).reshape(B * nkv * G, S, hd)
        vf = jnp.repeat(vv[:, None], G, 1).reshape(B * nkv * G, S, hd)
        out = ref.flash_attention_ref(qf, kf, vf, causal=causal,
                                      window=window)
        out = out.reshape(B * nkv, G, S, hd)
    else:
        out = _flash_pallas(qk, kk, vv, causal=causal, window=window,
                            interpret=_interpret())
    return out.reshape(B, nkv, G, S, hd).transpose(0, 3, 1, 2, 4) \
        .reshape(B, S, nh, hd)


def decode_attention(q: Array, k: Array, v: Array, valid: Array, *,
                     force_ref: bool = False) -> Array:
    """q [B,1,nh,hd]; k,v [B,C,nkv,hd]; valid [B,C] -> [B,1,nh,hd]."""
    B, _, nh, hd = q.shape
    C, nkv = k.shape[1], k.shape[2]
    G = nh // nkv
    qk = q.reshape(B, nkv, G, hd).reshape(B * nkv, G, hd)
    kk = k.transpose(0, 2, 1, 3).reshape(B * nkv, C, hd)
    vv = v.transpose(0, 2, 1, 3).reshape(B * nkv, C, hd)
    vd = jnp.repeat(valid[:, None, :], nkv, 1).reshape(B * nkv, C)
    if force_ref:
        out = ref.decode_attention_ref(qk, kk, vv, vd)
    else:
        out = _decode_pallas(qk, kk, vv, vd,
                             block_c=_divisor_block(C, 512),
                             interpret=_interpret())
    return out.reshape(B, 1, nh, hd)


def paged_decode_attention(q: Array, k_pool: Array, v_pool: Array,
                           block_tables: Array, pos: Array, *,
                           force_ref: bool = False) -> Array:
    """Model layout: q [B,1,nh,hd]; k/v_pool [P,bs,nkv,hd];
    block_tables [B,n_bt]; pos [B] -> [B,1,nh,hd].

    ``force_ref`` densifies the pool through the block table (gather +
    masked reference attend) — the cross-check path for the scalar-prefetch
    kernel.
    """
    B, _, nh, hd = q.shape
    P, bs, nkv = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    n_bt = block_tables.shape[1]
    G = nh // nkv
    qk = q.reshape(B, nkv, G, hd)
    if force_ref:
        C = n_bt * bs
        gather = jnp.clip(block_tables, 0, P - 1)
        kk = k_pool[gather].reshape(B, C, nkv, hd)
        vv = v_pool[gather].reshape(B, C, nkv, hd)
        valid = (jnp.arange(C)[None, :] <= pos[:, None]) \
            & jnp.repeat(block_tables < P, bs, axis=1)
        out = ref.decode_attention_ref(
            qk.reshape(B * nkv, G, hd),
            kk.transpose(0, 2, 1, 3).reshape(B * nkv, C, hd),
            vv.transpose(0, 2, 1, 3).reshape(B * nkv, C, hd),
            jnp.repeat(valid[:, None, :], nkv, 1).reshape(B * nkv, C))
        return out.reshape(B, 1, nh, hd)
    out = _paged_pallas(qk, k_pool, v_pool, block_tables, pos,
                        interpret=_interpret())
    return out.reshape(B, 1, nh, hd)


# ----------------------------------------------------------------- recurrent
def ssd_scan(x, dt, a, Bm, Cm, *, chunk: int = 128, force_ref: bool = False):
    if force_ref:
        return ref.ssd_scan_ref(x, dt, a, Bm, Cm)
    return _ssd_pallas(x, dt, a, Bm, Cm, chunk=chunk,
                       interpret=_interpret())


def rwkv6_scan(r, k, v, la, u, *, chunk: int = 64, force_ref: bool = False):
    if force_ref:
        return ref.rwkv_scan_ref(r, k, v, la, u)
    return _rwkv_pallas(r, k, v, la, u, chunk=chunk,
                        interpret=_interpret())


# ----------------------------------------------------------------------- ffn
def _divisor_block(n: int, target: int) -> int:
    b = min(target, n)
    while n % b:
        b -= 1
    return max(b, 1)


def fused_ffn(x, wg, wu, wd, *, force_ref: bool = False):
    if force_ref:
        return ref.fused_ffn_ref(x, wg, wu, wd)
    bt = _divisor_block(x.shape[1], 128)
    bf = _divisor_block(wg.shape[-1], 512)
    return _ffn_pallas(x, wg, wu, wd, block_t=bt, block_f=bf,
                       interpret=_interpret())
