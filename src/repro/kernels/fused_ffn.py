"""Pallas TPU fused SwiGLU FFN, batched over experts.

Computes y = (silu(x Wg) * (x Wu)) Wd in ONE pass: the [rows, d_ff]
intermediate never round-trips to HBM (on TPU this saves 2 * rows * d_ff
* bytes of HBM traffic per layer — the dominant cost of the unfused form
at large d_ff). The d_ff dimension is the innermost sequential grid axis;
partial down-projections accumulate in a VMEM f32 scratch.

Used for MoE experts ([E, cap, d] capacity layout) and, with E = 1, the
dense MLP.

Layouts:
    x  [E, T, d]
    wg, wu [E, d, f]
    wd [E, f, d]
    y  [E, T, d]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from ..compat import pallas_tpu_compiler_params

DEFAULT_BLOCK_T = 128
DEFAULT_BLOCK_F = 512


def _kernel(x_ref, wg_ref, wu_ref, wd_ref, y_ref, acc_ref, *,
            n_f_blocks: int):
    fj = pl.program_id(2)

    @pl.when(fj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                   # [bt, d]
    g = jax.lax.dot_general(x, wg_ref[0], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    u = jax.lax.dot_general(x, wu_ref[0], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)       # [bt, bf]
    acc_ref[...] += jax.lax.dot_general(
        h, wd_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(fj == n_f_blocks - 1)
    def _final():
        y_ref[0] = acc_ref[...].astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_f",
                                             "interpret"))
def fused_ffn(x, wg, wu, wd, *, block_t: int = DEFAULT_BLOCK_T,
              block_f: int = DEFAULT_BLOCK_F, interpret: bool = False):
    """x [E,T,d]; wg,wu [E,d,f]; wd [E,f,d] -> y [E,T,d]."""
    E, T, d = x.shape
    f = wg.shape[-1]
    block_t = min(block_t, T)
    block_f = min(block_f, f)
    assert T % block_t == 0 and f % block_f == 0
    nt, nf = T // block_t, f // block_f
    kernel = functools.partial(_kernel, n_f_blocks=nf)
    return pl.pallas_call(
        kernel,
        grid=(E, nt, nf),
        in_specs=[
            pl.BlockSpec((1, block_t, d), lambda e, i, j: (e, i, 0)),
            pl.BlockSpec((1, d, block_f), lambda e, i, j: (e, 0, j)),
            pl.BlockSpec((1, d, block_f), lambda e, i, j: (e, 0, j)),
            pl.BlockSpec((1, block_f, d), lambda e, i, j: (e, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_t, d), lambda e, i, j: (e, i, 0)),
        out_shape=jax.ShapeDtypeStruct((E, T, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_t, d), jnp.float32)],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, wg, wu, wd)
