"""Pallas TPU flash attention (prefill): online-softmax, causal, GQA-folded.

TPU adaptation notes: blocks are MXU-aligned (block_q = block_k = 128 by
default), the KV loop is the innermost *sequential* grid dimension so the
(m, l, acc) online-softmax state lives in VMEM scratch across KV steps, and
the GQA query-head group G is folded into the q-block rows so one kernel
invocation serves all query heads of a KV head (no KV duplication in HBM —
the contrast with a CUDA warp-per-head layout).

Layouts:
    q:  [Bkv, G, S, hd]   (Bkv = batch * n_kv_heads, G = q heads per kv head)
    k:  [Bkv, S, hd]
    v:  [Bkv, S, hd]
    out:[Bkv, G, S, hd]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from ..compat import pallas_tpu_compiler_params

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            block_q: int, block_k: int, n_kv_blocks: int, causal: bool,
            window: int | None, sm_scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = kj * block_k

    # skip blocks that are entirely masked out
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window is not None:
        run = jnp.logical_and(run, k_start + block_k - 1
                              > q_start - window)

    @pl.when(run)
    def _compute():
        G = q_ref.shape[1]
        q = q_ref[0].reshape(G * block_q, q_ref.shape[-1])
        k = k_ref[0]
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale   # [G*bq, bk]
        rows = jax.lax.broadcasted_iota(jnp.int32, (G * block_q, block_k), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (G * block_q, block_k), 1)
        q_pos = q_start + rows % block_q
        k_pos = k_start + cols
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new
        acc_ref[...] = acc

    @pl.when(kj == n_kv_blocks - 1)
    def _finalize():
        G = q_ref.shape[1]
        l = jnp.maximum(l_ref[...], 1e-30)
        out = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        o_ref[0] = out.reshape(G, block_q, o_ref.shape[-1])


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False):
    """q [Bkv, G, S, hd]; k, v [Bkv, S, hd] -> [Bkv, G, S, hd]."""
    Bkv, G, S, hd = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    nq, nk = S // block_q, S // block_k
    sm_scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, n_kv_blocks=nk,
        causal=causal, window=window, sm_scale=sm_scale)

    return pl.pallas_call(
        kernel,
        grid=(Bkv, nq, nk),
        in_specs=[
            pl.BlockSpec((1, G, block_q, hd), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, block_q, hd),
                               lambda b, i, j: (b, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * block_q, hd), jnp.float32),
            pltpu.VMEM((G * block_q,), jnp.float32),
            pltpu.VMEM((G * block_q,), jnp.float32),
        ],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
