"""Pallas TPU Mamba2 SSD chunked scan.

TPU adaptation of the GPU selective-scan: each grid step processes one
sequence chunk with dense MXU matmuls (decay-masked score matrix), and the
recurrent state [hd, ds] is carried across chunks in VMEM scratch — the
chunk axis is the innermost *sequential* grid dimension. The sequential
dependency is thus S/Q steps instead of S.

Layouts (heads flattened into the batch dim):
    x   [BH, S, hd]   head inputs
    dt  [BH, S]       softplus step sizes (>0)
    a   [BH, S]       log decay = A * dt  (< 0)
    Bm  [BH, S, ds]   input projections
    Cm  [BH, S, ds]   output projections
    y   [BH, S, hd]
    s_final [BH, hd, ds]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from ..compat import pallas_tpu_compiler_params

DEFAULT_CHUNK = 128


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, sf_ref, state_ref, *,
            n_chunks: int, chunk: int):
    cj = pl.program_id(1)

    @pl.when(cj == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # [Q, hd]
    dt = dt_ref[0].astype(jnp.float32)        # [Q]
    la = a_ref[0].astype(jnp.float32)         # [Q]
    Bm = b_ref[0].astype(jnp.float32)         # [Q, ds]
    Cm = c_ref[0].astype(jnp.float32)         # [Q, ds]

    cums = jnp.cumsum(la)                     # inclusive [Q]
    # intra-chunk: y_i += sum_{j<=i} exp(cums_i - cums_j) dt_j (C_i.B_j) x_j
    diff = cums[:, None] - cums[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(cols <= rows, jnp.exp(diff), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    scores = cb * decay * dt[None, :]         # [Qi, Qj]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk: y_i += C_i . (exp(cums_i) * S_prev)
    s_prev = state_ref[...]                   # [hd, ds]
    cin = jnp.exp(cums)[:, None] * Cm         # [Q, ds]
    y = y + jax.lax.dot_general(cin, s_prev, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: S = exp(sum la) S_prev + sum_j exp(cums_Q - cums_j) dt_j x_j B_j^T
    w = dt * jnp.exp(cums[-1] - cums)         # [Q]
    xw = x * w[:, None]                       # [Q, hd]
    s_new = jnp.exp(cums[-1]) * s_prev + jax.lax.dot_general(
        xw, Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # [hd, ds]
    state_ref[...] = s_new

    @pl.when(cj == n_chunks - 1)
    def _final():
        sf_ref[0] = s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, Bm, Cm, *, chunk: int = DEFAULT_CHUNK,
             interpret: bool = False):
    BH, S, hd = x.shape
    ds = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    kernel = functools.partial(_kernel, n_chunks=nc, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, chunk), lambda b, j: (b, j)),
            pl.BlockSpec((1, chunk), lambda b, j: (b, j)),
            pl.BlockSpec((1, chunk, ds), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, hd, ds), lambda b, j: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, hd), x.dtype),
            jax.ShapeDtypeStruct((BH, hd, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, ds), jnp.float32)],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, a, Bm, Cm)
