"""Pallas TPU kernels for the serving hot spots, with jnp oracles.

Each kernel: <name>.py (pl.pallas_call + BlockSpec), a wrapper in ops.py,
and an oracle in ref.py. On CPU the kernels execute in interpret mode.
"""
from .ops import (decode_attention, flash_attention, fused_ffn, rwkv6_scan,
                  ssd_scan)

__all__ = ["flash_attention", "decode_attention", "ssd_scan", "rwkv6_scan",
           "fused_ffn"]
