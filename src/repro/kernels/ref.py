"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Shapes use BH = batch * heads flattened leading dim unless noted.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def flash_attention_ref(q: Array, k: Array, v: Array,
                        causal: bool = True,
                        window: int | None = None) -> Array:
    """q,k,v [BH, S, hd] (kv already broadcast to query heads)."""
    S = q.shape[1]
    hd = q.shape[-1]
    s = jnp.einsum("bqh,bkh->bqk", q, k).astype(jnp.float32) / (hd ** 0.5)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bqk,bkh->bqh", w, v)


def decode_attention_ref(q: Array, k: Array, v: Array,
                         valid: Array) -> Array:
    """q [BH, G, hd]; k,v [BH, C, hd]; valid [BH, C] bool -> [BH, G, hd]."""
    hd = q.shape[-1]
    s = jnp.einsum("bgh,bch->bgc", q, k).astype(jnp.float32) / (hd ** 0.5)
    s = jnp.where(valid[:, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bgc,bch->bgh", w, v)


def ssd_scan_ref(x: Array, dt: Array, a: Array, Bm: Array, Cm: Array,
                 s0: Array | None = None):
    """Sequential Mamba2 SSD oracle.

    x [BH,S,hd], dt [BH,S], a [BH,S] log-decay (= A*dt, < 0),
    Bm/Cm [BH,S,ds]. Returns (y [BH,S,hd], s_final [BH,hd,ds]).
    """
    BH, S, hd = x.shape
    ds = Bm.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((BH, hd, ds), jnp.float32)

    def step(s, inp):
        xt, dtt, at, bt, ct = inp
        s = jnp.exp(at)[:, None, None] * s + \
            dtt[:, None, None] * jnp.einsum("bh,bs->bhs", xt, bt)
        y = jnp.einsum("bs,bhs->bh", ct, s)
        return s, y

    xs = (x.astype(jnp.float32).transpose(1, 0, 2), dt.transpose(1, 0),
          a.transpose(1, 0), Bm.astype(jnp.float32).transpose(1, 0, 2),
          Cm.astype(jnp.float32).transpose(1, 0, 2))
    sf, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2).astype(x.dtype), sf


def rwkv_scan_ref(r: Array, k: Array, v: Array, la: Array, u: Array,
                  s0: Array | None = None):
    """Sequential RWKV6 wkv oracle.

    r,k,v,la [BH,S,hd] (la log decay < 0), u [BH,hd].
    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T); S_t = diag(w_t) S_{t-1} + k_t v_t^T.
    Returns (y [BH,S,hd], s_final [BH,hd,hd]).
    """
    BH, S, hd = r.shape
    if s0 is None:
        s0 = jnp.zeros((BH, hd, hd), jnp.float32)

    def step(s, inp):
        rt, kt, vt, lat = inp
        kv = jnp.einsum("bt,bu->btu", kt, vt)
        y = jnp.einsum("bt,btu->bu", rt, s + u[:, :, None] * kv)
        s = jnp.exp(lat)[:, :, None] * s + kv
        return s, y

    xs = tuple(t.astype(jnp.float32).transpose(1, 0, 2)
               for t in (r, k, v, la))
    sf, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2).astype(r.dtype), sf


def fused_ffn_ref(x: Array, wg: Array, wu: Array, wd: Array) -> Array:
    """Batched SwiGLU FFN oracle. x [E,T,d]; wg,wu [E,d,f]; wd [E,f,d]."""
    g = jnp.einsum("etd,edf->etf", x, wg)
    u = jnp.einsum("etd,edf->etf", x, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("etf,efd->etd", h, wd)


def grouped_ffn_ref(x: Array, w_gate: Array, w_up: Array, w_down: Array,
                    group_sizes: Array) -> Array:
    """Grouped (per-expert) SwiGLU FFN oracle for the MoE kernel.

    x [T, d] sorted by expert; w_* [E, ...]; group_sizes [E] sums to T.
    """
    T, d = x.shape
    E = w_gate.shape[0]
    bounds = jnp.cumsum(group_sizes)
    eid = jnp.searchsorted(bounds, jnp.arange(T), side="right")
    g = jnp.einsum("td,tdf->tf", x, w_gate[eid])
    uu = jnp.einsum("td,tdf->tf", x, w_up[eid])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * uu
    return jnp.einsum("tf,tfd->td", h, w_down[eid])
