"""Pallas TPU RWKV6 wkv chunked scan (data-dependent per-channel decay).

Same chunking strategy as the SSD kernel, but the decay is a per-channel
vector (the RWKV6 "Finch" feature) and the bonus term u applies to the
current token only. State [hd, hd] carried in VMEM scratch across the
sequential chunk grid dimension.

Layouts:
    r, k, v, la [BH, S, hd]   (la = log decay < 0)
    u           [BH, hd]
    y           [BH, S, hd]
    s_final     [BH, hd, hd]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from ..compat import pallas_tpu_compiler_params

DEFAULT_CHUNK = 64


def _kernel(r_ref, k_ref, v_ref, la_ref, u_ref, y_ref, sf_ref, state_ref, *,
            n_chunks: int, chunk: int):
    cj = pl.program_id(1)

    @pl.when(cj == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)          # [Q, hd]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    la = la_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # [hd]

    cs = jnp.cumsum(la, axis=0)               # inclusive [Q, hd]
    ri = r * jnp.exp(cs - la)                 # decay to state BEFORE token i
    kj = k * jnp.exp(-cs)
    att = jax.lax.dot_general(ri, kj, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [Qi, Qj]
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(cols < rows, att, 0.0)    # strictly causal
    y = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # diagonal bonus: y_i += (r_i . (u * k_i)) v_i
    diag = jnp.sum(r * u[None, :] * k, axis=-1)
    y = y + diag[:, None] * v
    # inter-chunk: y_i += (r_i * exp(cs_i - la_i)) . S_prev
    s_prev = state_ref[...]
    y = y + jax.lax.dot_general(ri, s_prev, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    # state: S = diag(exp(cs_last)) S_prev + sum_j exp(cs_last - cs_j) k_j v_j^T
    kst = k * jnp.exp(cs[-1][None, :] - cs)   # [Q, hd]
    s_new = jnp.exp(cs[-1])[:, None] * s_prev + jax.lax.dot_general(
        kst, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    state_ref[...] = s_new

    @pl.when(cj == n_chunks - 1)
    def _final():
        sf_ref[0] = s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, la, u, *, chunk: int = DEFAULT_CHUNK,
               interpret: bool = False):
    BH, S, hd = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    kernel = functools.partial(_kernel, n_chunks=nc, chunk=chunk)
    seq_spec = pl.BlockSpec((1, chunk, hd), lambda b, j: (b, j, 0))
    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, hd), lambda b, j: (b, 0))],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, hd, hd), lambda b, j: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, hd), r.dtype),
            jax.ShapeDtypeStruct((BH, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, la, u)
