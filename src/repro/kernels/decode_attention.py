"""Pallas TPU decode attention: one query token vs a long KV cache.

The serving hot spot for decode_32k / long_500k: a single new token attends
to a cache of C past positions. This is HBM-bandwidth-bound (the whole cache
streams through once per step), so the kernel's job is to keep the VMEM
working set small and the stream contiguous: the cache is blocked along C
(innermost sequential grid dim) with online-softmax state in VMEM scratch,
and all G query heads of a KV head share each cache block load (GQA fold —
one cache read amortized over G heads, the key roofline lever when kv heads
are few, e.g. starcoder2's kv=2).

Layouts:
    q:     [Bkv, G, hd]
    k, v:  [Bkv, C, hd]
    valid: [Bkv, C]  bool (masks ring-buffer slots / unfilled capacity)
    out:   [Bkv, G, hd]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from ..compat import pallas_tpu_compiler_params

DEFAULT_BLOCK_C = 512
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, acc_ref, m_ref, l_ref, *,
            n_blocks: int, sm_scale: float):
    cj = pl.program_id(1)

    @pl.when(cj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                   # [G, hd]
    k = k_ref[0]                                   # [bc, hd]
    v = v_ref[0]
    ok = valid_ref[0]                              # [bc]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    s = jnp.where(ok[None, :], s, NEG_INF)         # [G, bc]

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(cj == n_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _paged_kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, n_bt: int, block_size: int,
                  n_blocks_pool: int, sm_scale: float):
    """Online-softmax decode over a slot's block list.

    ``bt_ref`` / ``pos_ref`` are scalar-prefetched (SMEM): the block table
    feeds the k/v BlockSpec index maps — each grid step DMAs exactly the
    one physical block the slot's logical block ``j`` maps to — and the
    kernel only masks. Same accumulator scheme as :func:`_kernel`.
    """
    b = pl.program_id(0)
    cj = pl.program_id(2)

    @pl.when(cj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]                                # [G, hd]
    k = k_ref[0, :, 0]                             # [bs, hd]
    v = v_ref[0, :, 0]
    pos = pos_ref[b]
    # logical position of each row of this block; sentinel blocks (table
    # entry == pool size) are fully masked
    off = jax.lax.broadcasted_iota(jnp.int32, (1, block_size), 1)[0]
    ok = (cj * block_size + off <= pos) & (bt_ref[b, cj] < n_blocks_pool)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    s = jnp.where(ok[None, :], s, NEG_INF)         # [G, bs]

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(cj == n_bt - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pool, v_pool, block_tables, pos, *,
                           interpret: bool = False):
    """One query token per slot over a paged KV pool.

    Layouts (one layer):
        q            [B, nkv, G, hd]
        k/v_pool     [P, bs, nkv, hd]
        block_tables [B, n_bt] int32 (entry P = unassigned sentinel)
        pos          [B] int32 position of the NEW token (slots <= pos
                     attend; the new token's KV must already be written)
    Returns [B, nkv, G, hd].

    The block table and positions ride scalar prefetch
    (``PrefetchScalarGridSpec``): the k/v index maps read
    ``block_tables[b, j]`` so the kernel streams exactly the slot's own
    physical blocks — the pool itself is never gathered or densified.
    """
    B, nkv, G, hd = q.shape
    P, bs = k_pool.shape[0], k_pool.shape[1]
    n_bt = block_tables.shape[1]
    kernel = functools.partial(_paged_kernel, n_bt=n_bt, block_size=bs,
                               n_blocks_pool=P,
                               sm_scale=1.0 / (hd ** 0.5))

    def kv_map(b, h, j, bt, pos):
        return (jnp.minimum(bt[b, j], P - 1), 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nkv, n_bt),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j, bt, pos:
                         (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), kv_map),
            pl.BlockSpec((1, bs, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, j, bt, pos:
                               (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(block_tables, jnp.int32), jnp.asarray(pos, jnp.int32),
      q, k_pool, v_pool)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def decode_attention(q, k, v, valid, *, block_c: int = DEFAULT_BLOCK_C,
                     interpret: bool = False):
    """q [Bkv,G,hd]; k,v [Bkv,C,hd]; valid [Bkv,C] -> [Bkv,G,hd]."""
    Bkv, G, hd = q.shape
    C = k.shape[1]
    block_c = min(block_c, C)
    assert C % block_c == 0
    nb = C // block_c
    kernel = functools.partial(_kernel, n_blocks=nb,
                               sm_scale=1.0 / (hd ** 0.5))
    return pl.pallas_call(
        kernel,
        grid=(Bkv, nb),
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_c, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_c, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_c), lambda b, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, valid)
