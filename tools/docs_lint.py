#!/usr/bin/env python
"""Documentation lint: dead links + undocumented examples.

Checks, over ``README.md`` and every ``docs/*.md``:

* every relative markdown link ``[text](path)`` (and bare relative image
  reference) resolves to a file or directory inside the repo, after
  stripping any ``#anchor`` fragment — absolute URLs are ignored;
* every ``examples/*.py`` script is referenced by name from at least one
  documentation page, so new examples cannot land undocumented.

Run from the repo root (CI does): ``python tools/docs_lint.py``.
Exit status 0 = clean, 1 = problems (each printed on its own line).
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE = re.compile(r"^```.*?^```", re.M | re.S)
CODE = re.compile(r"`[^`]*`")


def prose(page: pathlib.Path) -> str:
    """Page text with fenced blocks and inline code spans removed, so
    bracketed math like ``E[T](l)`` is never mistaken for a link."""
    return CODE.sub("", FENCE.sub("", page.read_text()))


def doc_pages() -> list[pathlib.Path]:
    pages = []
    readme = ROOT / "README.md"
    if readme.exists():
        pages.append(readme)
    pages.extend(sorted((ROOT / "docs").glob("*.md")))
    return pages


def check_links(pages) -> list[str]:
    problems = []
    for page in pages:
        for target in LINK.findall(prose(page)):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # http:, mailto:
                continue
            if target.startswith("#"):                     # same-page anchor
                continue
            path = target.split("#", 1)[0]
            resolved = (page.parent / path).resolve()
            if not resolved.exists():
                problems.append(
                    f"{page.relative_to(ROOT)}: dead link -> {target}")
            elif ROOT not in resolved.parents and resolved != ROOT:
                problems.append(
                    f"{page.relative_to(ROOT)}: link escapes repo -> {target}")
    return problems


def check_examples_referenced(pages) -> list[str]:
    corpus = "\n".join(p.read_text() for p in pages)
    problems = []
    for script in sorted((ROOT / "examples").glob("*.py")):
        if script.name not in corpus:
            problems.append(
                f"examples/{script.name}: not referenced by README.md "
                "or any docs/*.md page")
    return problems


def main() -> int:
    pages = doc_pages()
    if not pages:
        print("docs lint: no README.md or docs/*.md pages found")
        return 1
    problems = check_links(pages) + check_examples_referenced(pages)
    for p in problems:
        print(p)
    n_links = sum(len(LINK.findall(prose(p))) for p in pages)
    status = f"{len(problems)} problem(s)" if problems else "clean"
    print(f"docs lint: {len(pages)} page(s), {n_links} link(s), "
          f"{len(list((ROOT / 'examples').glob('*.py')))} example(s) "
          f"-- {status}")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
