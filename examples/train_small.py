"""Train a small decoder for a few hundred steps on the synthetic pipeline.

    PYTHONPATH=src python examples/train_small.py --steps 150
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import DataConfig, SyntheticTokens
from repro.models import reduced
from repro.train import (AdamWConfig, init_train_state, make_train_step,
                         save_checkpoint)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), d_model=256)
    data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                      batch_size=8))
    opt = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps,
                      weight_decay=0.0)
    step_fn = jax.jit(make_train_step(cfg, opt))
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    t0 = time.time()
    first = None
    for i in range(args.steps):
        state, m = step_fn(state, {"tokens":
                                   jnp.asarray(data.batch(i)["tokens"])})
        loss = float(m["loss"])
        first = first if first is not None else loss
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {loss:.4f} "
                  f"({(time.time()-t0):.1f}s)", flush=True)
    print(f"loss {first:.3f} -> {loss:.3f}")
    if args.ckpt:
        save_checkpoint(args.ckpt, state, step=args.steps)
        print("checkpoint saved to", args.ckpt)


if __name__ == "__main__":
    main()
