"""Observability walkthrough: trace a serving run, open it in Perfetto.

Instruments the closed-loop replay harness end to end with the ``obs``
subsystem and shows each output surface:

1. **Request tracing** — every completed request becomes a span tree
   (admit -> prefill -> decode -> retire) on the simulated-clock
   timeline, with controller re-solves marked as instants and wall-clock
   solver spans on a second track. The trace is written as standard
   Chrome trace-event JSON: drag ``obs_trace.json`` onto
   https://ui.perfetto.dev (or ``chrome://tracing``) and you get a
   zoomable per-request waterfall of the whole run, plus a
   tokens-in-flight counter track.
2. **Streaming histograms** — wait / service / system-time distributions
   folded per control block into log-bucketed histograms (exact-bound
   percentiles, <3.2% relative error at the default 5 bits).
3. **Drift monitor** — predicted-vs-measured wait comparison at the
   estimator's operating point; in ``resolve_mode="drift"`` the
   controller re-solves on the alarm rather than a blind block cadence.
4. **Compile guards** — every jitted entry point is labeled through
   ``compat.jit``; after the run, one trace per entry point proves the
   ragged budgets never caused a recompile storm.

    PYTHONPATH=src python examples/observe_serving.py
"""
import json

import numpy as np

from repro.core import paper_problem
from repro.obs import (MetricsRegistry, Tracer, jax_hooks,
                       validate_request_trees)
from repro.queueing_sim import Segment, generate_drift_trace
from repro.serving import ReplayConfig, ReplayHarness

TRACE_PATH = "obs_trace.json"


def main():
    prob = paper_problem()
    # a drifting workload: arrival rate more than doubles mid-stream
    trace = generate_drift_trace(
        prob.tasks, [Segment(3000, 0.2), Segment(3000, 0.45)], seed=42)

    tracer = Tracer()
    metrics = MetricsRegistry()
    harness = ReplayHarness(
        prob,
        ReplayConfig(block_size=128, resolve_mode="drift"),
        tracer=tracer, metrics=metrics)
    result = harness.run_virtual(trace)
    report = result.report(prob)

    print("=== run ===")
    print(f"requests served      : {report.n}")
    print(f"controller re-solves : {result.n_resolves} "
          f"(drift-gated, not cadence)")
    print(f"mean wait            : {report.mean_wait:.3f} s")

    print("\n=== streaming percentiles (per-block histogram folds) ===")
    snap = metrics.snapshot()
    for name in ("replay.wait", "replay.system_time"):
        d = snap[name].as_dict()
        print(f"{name:<20} p50={d['p50']:.3f}  p90={d['p90']:.3f}  "
              f"p99={d['p99']:.3f}  (n={d['n']})")
    print("exact report fields  :", {k: round(v, 3) for k, v in
                                     report.wait_percentiles.items()})

    print("\n=== drift monitor (predicted vs measured) ===")
    last = report.drift
    print(f"reason={last['reason']}  rel_err={last['rel_err']:.3f}  "
          f"rho={last['rho']:.3f}  strikes={last['strikes']}")

    print("\n=== compile guards ===")
    print(json.dumps(jax_hooks.snapshot(), indent=2))
    print("(a virtual-clock replay dispatches no engine, so counts are "
          "empty; real-token runs show one trace per labeled jit entry "
          "point — see tests/test_obs_jax_hooks.py)")

    # the acceptance contract: a complete, well-formed span tree for
    # EVERY request, programmatically checked before export
    info = validate_request_trees(tracer.to_chrome(), range(trace.n))
    tracer.dump(TRACE_PATH)
    print(f"\n=== trace ===\n{info['n_events']} events, "
          f"{info['n_requests']} validated request trees")
    print(f"wrote {TRACE_PATH} — open it at https://ui.perfetto.dev "
          "(Ctrl+O / drag-and-drop), then:")
    print("  * process 'queueing timeline (virtual clock)': per-request "
          "admit/prefill/decode spans + re-solve instants;")
    print("  * process 'engine (wall clock)': controller.resolve solver "
          "spans;")
    print("  * the replay.tokens_in_flight counter track shows load "
          "ramping at the drift point.")


if __name__ == "__main__":
    main()
