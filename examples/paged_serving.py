"""Occupancy-limited vs slot-limited admission: the paged-KV walkthrough.

The dense slot engine admits a request iff a fixed-capacity slot is free:
memory is committed at WORST-CASE granularity, so short requests strand
most of their slot and concurrency is capped at ``max_slots`` no matter
how small the requests are. The paged engine carves the same KV memory
into fixed-size blocks, reserves only ``prompt + budget + max_extra - 1``
tokens' worth per admission, and grows each request's block list lazily —
admission is limited by tokens actually spoken for, not by slot count.

This script serves one short-request workload through both engines at
EQUAL total KV memory and prints, step by step, who is admitted, how full
the pool is, and what that buys in concurrent tokens-in-use — then checks
the two engines emitted token-for-token identical streams (the paged
exactness contract), so the density is free.

Finally it closes the analytics loop: higher admitted concurrency means
higher decode occupancy, which slows every member's tokens; the
batch-service model (``core.batch_service``) prices exactly that
feedback when budgets are chosen.

    PYTHONPATH=src python examples/paged_serving.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.batch_service import StepLatencyModel, batch_service_wait
from repro.core.params import paper_tasks
from repro.models import init_params, reduced
from repro.serving.continuous import ContinuousBatchingEngine

POOL_TOKENS = 512          # both engines own exactly this much KV
CAPACITY = 64              # per-request logical cap (dense slot size)


def make_workload(n=24, seed=0):
    """Short requests: ~18 lifetime tokens each, under a third of a slot."""
    rng = np.random.default_rng(seed)
    return [(i, rng.integers(1, 97, size=8).astype(np.int32), 8, 2)
            for i in range(n)]


def serve(eng, reqs, label):
    pending = list(reqs)
    done = {}
    print(f"\n=== {label}: pool={eng.pool_tokens} tokens ===")
    step = 0
    while pending or eng.n_active:
        if pending:
            ok = eng.admit_many(pending)
            n_adm = sum(ok)
            pending = [r for r, f in zip(pending, ok) if not f]
            if n_adm:
                print(f"step {step:3d}: admitted {n_adm:2d} "
                      f"(queued {len(pending):2d})  "
                      f"active={eng.n_active:2d}  "
                      f"tokens_in_use={eng.tokens_in_use:3d}  "
                      f"pool_fill={eng.pool_fill:.0%}")
        for s in eng.step_chunk():
            done[s.rid] = s.tokens
        step += 1
    print(f"done: {len(done)} requests in {step} fused chunks")
    return done


def main():
    cfg = reduced(get_config("qwen3-0.6b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    reqs = make_workload()

    # slot-limited: 8 dense 64-token slots = 512 tokens, concurrency <= 8
    slot = ContinuousBatchingEngine(cfg, params, max_slots=8,
                                    capacity=CAPACITY, chunk=4)
    # occupancy-limited: same 512 tokens as 64 blocks of 8; each request
    # reserves ceil(17/8) + prompt blocks = 24 tokens -> up to 16 rows
    # busy at once from the same memory
    paged = ContinuousBatchingEngine(cfg, params, max_slots=16,
                                     capacity=CAPACITY, chunk=4,
                                     paged=True, block_size=8, n_blocks=64)
    assert slot.pool_tokens == paged.pool_tokens == POOL_TOKENS

    done_slot = serve(slot, reqs, "slot-limited admission (dense)")
    done_paged = serve(paged, reqs, "occupancy-limited admission (paged)")

    assert done_paged == done_slot, "streams must match token-for-token"
    print("\ntoken streams identical across both engines (greedy contract)")

    # the feedback the allocator must price: doubling admitted occupancy
    # slows each member's tokens by r(b) = t_step(b)/t_step(1)
    print("\n=== occupancy-corrected queueing at the denser operating point"
          " ===")
    model = StepLatencyModel(d0=0.02, d1=0.004)   # affine step latency
    tasks = paper_tasks()
    lengths = np.full(tasks.n_tasks, 120.0)
    for max_batch in (8, 16):
        res = batch_service_wait(tasks, lengths, lam=1.5, model=model,
                                 max_batch=max_batch)
        print(f"max_batch={max_batch:2d}: occupancy b_bar={res.b_bar:5.2f} "
              f"token slowdown r={res.ratio:5.3f}  "
              f"E[wait]={res.mean_wait:7.3f}s  "
              f"E[system]={res.mean_system_time:7.3f}s")
    print("denser admission trades per-token speed for queueing delay; "
          "sweeps.solve_grid_batch_service solves budgets at this "
          "fixed point.")


if __name__ == "__main__":
    main()
