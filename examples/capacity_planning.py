"""Beyond-paper study: capacity planning with the queueing-aware allocator.

Sweeps arrival rate and replica count: how the optimal budgets shrink under
load (the accuracy-latency tradeoff tightening) and how M/G/c replication
buys utility back. The whole load sweep is now solved in ONE vmapped grid
call (``repro.sweeps.solve_grid``; the scalar facade is cross-checked at
one operating point), every operating point is validated by Monte-Carlo in
one batched Lindley call, and the solved grid answers the capacity
questions directly: Pareto frontier, heavy-traffic (rho_0 -> 1) behaviour,
and "max sustainable lambda at target accuracy".

    PYTHONPATH=src python examples/capacity_planning.py
"""
import numpy as np

from repro.core import ServerParams, Problem, paper_problem, solve_mgc
from repro.queueing_sim import sweep
from repro.sweeps import (heavy_traffic_slice, max_sustainable_lambda,
                          pareto_front, reference_check, solve_grid)


def main():
    base = paper_problem()
    lams = (0.05, 0.1, 0.2, 0.3, 0.4, 0.5)
    print("=== load sweep (single server, one grid solve) ===")
    grid = solve_grid(base.tasks, np.asarray(lams), base.server.alpha,
                      base.server.l_max)
    # scalar reference: the facade the serving stack uses must agree
    reference_check(base.tasks, grid, cells=[1])

    # DES validation: the full (lambda x policy) grid in one vectorized
    # call — every lambda's traffic against every lambda's optimal budgets
    # (6 x 6 x 8 seeds x 10k queries). The diagonal validates each solve;
    # the off-diagonal cells measure how much a load-mismatched allocation
    # costs, i.e. why the allocation must be queueing-aware at all.
    policies = {f"lam_{lam}": np.asarray(grid.lengths_int[i])
                for i, lam in enumerate(lams)}
    des = sweep(base, policies, lams=list(lams), n_seeds=8,
                n_queries=10_000, seed=0, clip_unstable=False)
    print(f"{'lam':>6} {'J':>9} {'J_des':>9} {'+-':>7} {'rho':>6} "
          f"{'util':>6} {'mismatch':>9}  budgets")
    for i, lam in enumerate(lams):
        p = list(des.policy_names).index(f"lam_{lam}")
        # worst regret from serving this traffic with another load's budgets
        mismatch = float(des.objective[i, p] - des.objective[i].min())
        print(f"{lam:6.2f} {grid.value_cont[i]:9.4f} "
              f"{des.objective[i, p]:9.4f} {des.ci_objective[i, p]:7.4f} "
              f"{des.rho_analytic[i, p]:6.3f} {des.utilization[i, p]:6.3f} "
              f"{mismatch:9.4f}  "
              f"{np.round(grid.lengths_cont[i]).astype(int)}")
    matched_best = all(
        des.objective[i, list(des.policy_names).index(f'lam_{lam}')]
        >= des.objective[i].max() - 2 * des.ci_objective[i].max()
        for i, lam in enumerate(lams))
    print(f"load-matched budgets best at every lambda (within 2 CI): "
          f"{matched_best}")

    print("\n=== capacity queries on the solved grid ===")
    pf = pareto_front(grid)
    print("accuracy/E[T_sys] Pareto frontier (undominated load points):")
    for a, t, lam in zip(pf["accuracy"], pf["system_time"], pf["lam"]):
        print(f"  lam={lam:5.2f}  accuracy={a:.4f}  E[T_sys]={t:7.3f}s")
    for target in (0.40, 0.30):
        q = max_sustainable_lambda(base.tasks, base.server.alpha,
                                   base.server.l_max, min_accuracy=target,
                                   n_grid=17, refine=1)
        print(f"max sustainable lambda at accuracy >= {target}: "
              f"{q['lam']:.3f} q/s (accuracy {q['accuracy']:.4f}, "
              f"E[T_sys] {q['system_time']:.3f}s)")

    print("\n=== heavy traffic: rho_0 -> 1 slice ===")
    ht = heavy_traffic_slice(base.tasks, base.server.alpha,
                             base.server.l_max, [0.5, 0.9, 0.95, 0.98])
    for i in range(ht.n_cells):
        print(f"rho_0={ht.lam[i] * np.sum(np.asarray(base.tasks.pi) * np.asarray(base.tasks.t0)):.3f} "
              f"lam={ht.lam[i]:6.3f}  rho*={ht.rho_int[i]:.3f}  "
              f"budgets={ht.lengths_int[i].astype(int)}  "
              f"J={ht.value_int[i]:8.4f}")
    print("reading: approaching saturation the allocator sheds thinking "
          "tokens entirely — stability eats the whole accuracy budget.")

    print("\n=== replica sweep at lam=0.5 (M/G/c approximation) ===")
    prob = Problem(tasks=base.tasks, server=ServerParams(0.5, 30.0, 32768.0))
    for c in (1, 2, 4, 8):
        r = solve_mgc(prob, c)
        print(f"c={c}: J={float(r.value):8.4f}  "
              f"budgets={np.round(np.asarray(r.lengths)).astype(int)}")
    print("\nreading: replication relaxes the queueing penalty, so the "
          "allocator re-spends the slack on thinking tokens for the "
          "tasks with the steepest accuracy curves.")


if __name__ == "__main__":
    main()
