"""Beyond-paper study: capacity planning with the queueing-aware allocator.

Sweeps arrival rate and replica count: how the optimal budgets shrink under
load (the accuracy-latency tradeoff tightening) and how M/G/c replication
buys utility back. Every operating point on the load sweep is validated by
Monte-Carlo: one batched Lindley call simulates the whole (lambda x policy
x seed) grid and reports the realized objective next to the analytic one.

    PYTHONPATH=src python examples/capacity_planning.py
"""
import numpy as np

from repro.core import (ServerParams, Problem, paper_problem, solve,
                        solve_mgc)
from repro.queueing_sim import sweep


def main():
    base = paper_problem()
    lams = (0.05, 0.1, 0.2, 0.3, 0.4, 0.5)
    print("=== load sweep (single server) ===")
    sols = {}
    for lam in lams:
        prob = Problem(tasks=base.tasks,
                       server=ServerParams(lam, 30.0, 32768.0))
        sols[lam] = solve(prob)

    # DES validation: the full (lambda x policy) grid in one vectorized
    # call — every lambda's traffic against every lambda's optimal budgets
    # (6 x 6 x 8 seeds x 10k queries). The diagonal validates each solve;
    # the off-diagonal cells measure how much a load-mismatched allocation
    # costs, i.e. why the allocation must be queueing-aware at all.
    policies = {f"lam_{lam}": np.asarray(sols[lam].lengths_int)
                for lam in lams}
    des = sweep(base, policies, lams=list(lams), n_seeds=8,
                n_queries=10_000, seed=0, clip_unstable=False)
    print(f"{'lam':>6} {'J':>9} {'J_des':>9} {'+-':>7} {'rho':>6} "
          f"{'util':>6} {'mismatch':>9}  budgets")
    for i, lam in enumerate(lams):
        sol = sols[lam]
        p = list(des.policy_names).index(f"lam_{lam}")
        # worst regret from serving this traffic with another load's budgets
        mismatch = float(des.objective[i, p] - des.objective[i].min())
        print(f"{lam:6.2f} {sol.value_cont:9.4f} "
              f"{des.objective[i, p]:9.4f} {des.ci_objective[i, p]:7.4f} "
              f"{des.rho_analytic[i, p]:6.3f} {des.utilization[i, p]:6.3f} "
              f"{mismatch:9.4f}  {np.round(sol.lengths_cont).astype(int)}")
    matched_best = all(
        des.objective[i, list(des.policy_names).index(f'lam_{lam}')]
        >= des.objective[i].max() - 2 * des.ci_objective[i].max()
        for i, lam in enumerate(lams))
    print(f"load-matched budgets best at every lambda (within 2 CI): "
          f"{matched_best}")

    print("\n=== replica sweep at lam=0.5 (M/G/c approximation) ===")
    prob = Problem(tasks=base.tasks, server=ServerParams(0.5, 30.0, 32768.0))
    for c in (1, 2, 4, 8):
        r = solve_mgc(prob, c)
        print(f"c={c}: J={float(r.value):8.4f}  "
              f"budgets={np.round(np.asarray(r.lengths)).astype(int)}")
    print("\nreading: replication relaxes the queueing penalty, so the "
          "allocator re-spends the slack on thinking tokens for the "
          "tasks with the steepest accuracy curves.")


if __name__ == "__main__":
    main()
