"""Beyond-paper study: capacity planning with the queueing-aware allocator.

Sweeps arrival rate and replica count: how the optimal budgets shrink under
load (the accuracy-latency tradeoff tightening) and how M/G/c replication
buys utility back.

    PYTHONPATH=src python examples/capacity_planning.py
"""
import numpy as np

from repro.core import (ServerParams, Problem, paper_problem, solve,
                        solve_mgc)


def main():
    base = paper_problem()
    print("=== load sweep (single server) ===")
    print(f"{'lam':>6} {'J':>9} {'rho':>6}  budgets")
    for lam in (0.05, 0.1, 0.2, 0.3, 0.4, 0.5):
        prob = Problem(tasks=base.tasks,
                       server=ServerParams(lam, 30.0, 32768.0))
        sol = solve(prob)
        from repro.core import service_moments
        import jax.numpy as jnp
        rho = float(service_moments(prob.tasks,
                                    jnp.asarray(sol.lengths_cont),
                                    lam).rho)
        print(f"{lam:6.2f} {sol.value_cont:9.4f} {rho:6.3f}  "
              f"{np.round(sol.lengths_cont).astype(int)}")

    print("\n=== replica sweep at lam=0.5 (M/G/c approximation) ===")
    prob = Problem(tasks=base.tasks, server=ServerParams(0.5, 30.0, 32768.0))
    for c in (1, 2, 4, 8):
        r = solve_mgc(prob, c)
        print(f"c={c}: J={float(r.value):8.4f}  "
              f"budgets={np.round(np.asarray(r.lengths)).astype(int)}")
    print("\nreading: replication relaxes the queueing penalty, so the "
          "allocator re-spends the slack on thinking tokens for the "
          "tasks with the steepest accuracy curves.")


if __name__ == "__main__":
    main()
