"""Overload drill: the degradation ladder riding out an arrival burst.

A deterministic fire drill for the serving stack. The paper problem is
re-rated to rho = 0.6 at its own oracle budgets, then hit with a
seeded fault bank (``repro.faults``): an 8x compressed-arrival burst,
2% straggler services, 2% NaN-poisoned observations, and 2% dropped
completions. The ``AdmissionController`` sits in front of the closed
replay loop with a three-level budget-degradation ladder anchored at
the deployed solution; the drift-gated re-solver runs behind it.

Watch for the three phases:

1. steady state — level 0, budgets at the oracle, small waits;
2. the burst — the estimated rho at the level-0 budgets crosses the
   hysteresis threshold, the ladder walks down (budget caps halving per
   level), waits peak and drain instead of diverging;
3. recovery — after the dwell time continuously calm the ladder walks
   back up, and the level transitions force re-solves that land the
   budgets back at the clairvoyant solution.

    PYTHONPATH=src python examples/overload_drill.py
"""
import dataclasses

import numpy as np

from repro.core import paper_problem
from repro.core.allocator import solve
from repro.faults import (ArrivalBurst, DroppedCompletions, FaultSet,
                          ObservationCorruption, StragglerDecode)
from repro.obs.monitor import DriftMonitor
from repro.queueing_sim import Segment, generate_drift_trace
from repro.serving import (AdmissionConfig, AdmissionController,
                           ReplayConfig, ReplayHarness)


def main():
    prob = paper_problem()
    oracle = np.asarray(solve(prob).lengths_int, dtype=np.int64)
    pi = np.asarray(prob.tasks.pi)
    es = float(np.sum(pi * (np.asarray(prob.tasks.t0)
                            + np.asarray(prob.tasks.c) * oracle)))
    lam0 = 0.6 / es                       # rho = 0.6 at the paper oracle
    hot = dataclasses.replace(
        prob, server=dataclasses.replace(prob.server, lam=lam0))
    oracle_hot = np.asarray(solve(hot).lengths_int, dtype=np.int64)

    print("=== overload drill ===")
    print(f"lam = {lam0:.4f}/s, oracle budgets "
          f"{[int(v) for v in oracle_hot]}")
    adm = AdmissionController(
        oracle_hot, hot.server.l_max,
        AdmissionConfig(rho_high=0.85, rho_low=0.6, dwell_down=800.0))
    print("degradation ladder (budget caps per level):")
    for j, row in enumerate(adm.ladder()):
        print(f"  level {j}: {[int(v) for v in row]}")

    trace = generate_drift_trace(hot.tasks, [Segment(10_000, lam0)],
                                 seed=13)
    faults = FaultSet(
        ArrivalBurst(t0=8000.0, t1=20_000.0, factor=8.0),
        StragglerDecode(rate=0.02, multiplier=2.0, seed=1),
        ObservationCorruption(rate=0.02, mode="nan", seed=2),
        DroppedCompletions(rate=0.02, seed=3))
    h = ReplayHarness(hot,
                      ReplayConfig(block_size=256, resolve_mode="drift",
                                   est_halflife=128.0),
                      monitor=DriftMonitor(), admission=adm, faults=faults)
    res = h.run_virtual(trace)

    print("\nblock timeline (one row per control block):")
    print(f"{'t_start':>9} {'level':>5} {'shed':>4} {'resolve':>7} "
          f"{'mean_wait':>9} {'rho_hat':>7}  deployed budgets")
    for b in res.blocks:
        mark = "  <-- burst" if 8000.0 <= b.t_start <= 9600.0 else ""
        print(f"{b.t_start:9.0f} {b.level:5d} {b.n_shed:4d} "
              f"{'yes' if b.resolved else '':>7} {b.mean_wait:9.2f} "
              f"{b.estimator['rho']:7.3f}  "
              f"{[int(v) for v in b.budgets]}{mark}")

    rep = res.report(hot)
    snap = res.admission
    print("\n=== outcome ===")
    print(f"goodput             {rep.goodput:.4f} correct/s "
          f"(accuracy {rep.accuracy:.3f})")
    print(f"shed                {rep.n_shed} requests "
          f"({rep.shed_fraction:.1%})")
    print(f"degradation occupancy "
          f"{ {k: round(v, 4) for k, v in rep.degradation_occupancy.items()} }")
    print(f"level transitions   {snap['n_level_up']} up, "
          f"{snap['n_level_down']} down (final level {snap['level']})")
    print(f"re-solves           {res.n_resolves} "
          f"(skipped observations: {res.estimator_state['n_skipped']})")
    gap = int(np.max(np.abs(res.final_budgets - oracle_hot)))
    print(f"final budgets       {[int(v) for v in res.final_budgets]} "
          f"(oracle {[int(v) for v in oracle_hot]}, L-inf gap {gap})")
    assert snap["level"] == 0 and gap <= 32, "drill did not recover"
    print("\nrecovered: ladder back at level 0, budgets back at the "
          "oracle.")


if __name__ == "__main__":
    main()
