"""End-to-end serving scenario: the paper's system as a running server.

Streams 3000 Poisson queries through the allocator-driven FIFO server
(virtual clock at production scale), compares disciplines and batching,
then demonstrates the REAL decode path: a reduced Qwen3-family model
generating budget-enforced tokens on CPU.

    PYTHONPATH=src python examples/serve_stream.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core import paper_problem, ServerParams, Problem
from repro.models import init_params, reduced
from repro.queueing_sim import generate_stream, pk_prediction
from repro.serving import DecodeEngine, LLMServer, ServerConfig


def main():
    prob = paper_problem()
    stream = generate_stream(prob.tasks, prob.server.lam, 3000, seed=0)

    print("=== virtual-clock serving at production scale ===")
    for label, cfg in {
        "fifo (paper)": ServerConfig(online_adaptation=False),
        "sjf": ServerConfig(discipline="sjf", online_adaptation=False),
        "priority": ServerConfig(discipline="priority",
                                 online_adaptation=False),
        "batched x4": ServerConfig(batch_size=4, online_adaptation=False),
        "online-adaptive": ServerConfig(online_adaptation=True),
    }.items():
        srv = LLMServer(prob, cfg)
        rep = srv.run(stream)
        print(f"{label:16s} J={rep.objective:7.4f} "
              f"wait={rep.mean_wait:6.3f}s sys={rep.mean_system_time:6.3f}s "
              f"acc={rep.mean_accuracy_prob:.3f}")
    pred = pk_prediction(prob, list(LLMServer(prob).allocator
                                    .solution.lengths_int))
    print(f"P-K predicted system time: {pred['mean_system_time']:.3f}s")

    print("\n=== real engine: budget-enforced decode (reduced model) ===")
    cfg = reduced(get_config("qwen3-0.6b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = DecodeEngine(cfg, params, cache_capacity=512)
    small = Problem(tasks=prob.tasks, server=ServerParams(0.1, 2.0, 64.0))
    small_stream = generate_stream(small.tasks, 0.1, 16, seed=1,
                                   prompt_len_range=(4, 12))
    srv = LLMServer(small, ServerConfig(generate_tokens=True,
                                        max_extra_tokens=2,
                                        online_adaptation=False),
                    engine=engine)
    rep = srv.run(small_stream)
    print(f"served {rep.n} requests, generated {rep.tokens_generated} real "
          f"tokens; budgets: {rep.per_task_budget}")


if __name__ == "__main__":
    main()
