"""Quickstart: solve the paper's token-allocation problem and validate it.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (objective, paper_problem, sandwich, solve)
from repro.queueing_sim import generate_stream, pk_prediction, simulate
from repro.compat import enable_x64


def main():
    # 1. The calibrated problem from the paper (Table I, lam=0.1, alpha=30)
    prob = paper_problem()
    print("tasks:", prob.tasks.names)

    # 2. Solve: projected fixed point (Lambert-W closed form) + integer proj.
    sol = solve(prob)
    print("\noptimal continuous budgets l*:")
    for n, l in zip(prob.tasks.names, sol.lengths_cont):
        print(f"  {n:15s} {l:8.1f}")
    print("integer budgets:", dict(zip(prob.tasks.names,
                                       sol.lengths_int.astype(int))))
    print(f"J(l*) = {sol.value_cont:.4f}  (method: {sol.method}, "
          f"{sol.iterations} iters)")

    # 3. The eq-41 sandwich: continuous >= integer >= lower bound
    import jax
    with enable_x64():
        s = sandwich(prob, jnp.asarray(sol.lengths_cont))
    print(f"\nsandwich: J_cont={s['J_continuous']:.6f} >= "
          f"J_int={s['J_int_exhaustive']:.6f} >= "
          f"J_bar={s['J_bar_lower_bound']:.6f}")

    # 4. Validate the queueing analysis against a 10k-query DES
    stream = generate_stream(prob.tasks, prob.server.lam, 10_000, seed=0)
    res = simulate(prob, sol.lengths_int, stream)
    pred = pk_prediction(prob, list(sol.lengths_int))
    print(f"\nDES mean system time: {res.mean_system_time:.3f}s | "
          f"P-K predicts {pred['mean_system_time']:.3f}s")
    print(f"DES objective {res.objective:.4f} | analytic "
          f"{float(objective(prob, jnp.asarray(np.asarray(sol.lengths_int, float)))):.4f}")

    # 5. Compare against uniform budgeting (paper Fig 3)
    for u in (0, 100, 500):
        r = simulate(prob, np.full(6, float(u)), stream)
        print(f"uniform {u:4d}: J_des={r.objective:8.4f} "
              f"(optimal gains {res.objective - r.objective:+.3f})")


if __name__ == "__main__":
    main()
