"""Prediction-error robustness frontier: how wrong can the predictor be?

The paper assumes the task type (hence its token budget and service
time) is known on arrival. Real schedulers only have a *predicted*
length. This example runs the SPJF/SPRPT predicted disciplines against
exact-size SJF/SRPT and size-blind FIFO across a grid of prediction
error levels (mean-one multiplicative log-normal, sigma = 0 is a perfect
oracle) on a heavy-tailed policy, and reports the error level at which
FIFO wins back the p99 tail — the ``fifo_crossover_sigma``.

    PYTHONPATH=src python examples/prediction_frontier.py
"""
import numpy as np

from repro.core import paper_problem
from repro.data import calibrate_from_synthetic
from repro.sweeps import (fifo_crossover_sigma, service_cv2,
                          sweep_prediction_error)

# all reasoning budget on one task type: service CV^2 ~ 4.7, the regime
# where size-based scheduling wins the tail at zero error
HEAVY = np.array([2000.0, 0.0, 0.0, 0.0, 0.0, 0.0])


def main():
    prob = paper_problem()
    cv2 = service_cv2(prob, HEAVY)
    t = np.asarray(prob.tasks.t0) + np.asarray(prob.tasks.c) * HEAVY
    es = float(np.sum(np.asarray(prob.tasks.pi) * t))
    lam = 0.8 / es                                   # rho = 0.8
    sigmas = np.array([0.0, 0.3, 0.6, 1.0, 1.5, 2.0])
    print(f"policy CV^2 = {cv2:.2f}, rho = 0.8, "
          f"sigmas = {sigmas.tolist()}")

    fr = sweep_prediction_error(prob, HEAVY, np.array([lam]), sigmas,
                                n_seeds=8, n_queries=2000, seed=0)

    print(f"\n{'sigma':>6} {'FIFO':>8} {'SJF':>8} {'SRPT':>8} "
          f"{'SPJF':>8} {'SPRPT':>8}   (mean wait, s)")
    f, sj, sr = (fr.mean_wait[d][0] for d in ("fifo", "sjf", "srpt"))
    for g, sg in enumerate(sigmas):
        print(f"{sg:6.2f} {f:8.3f} {sj:8.3f} {sr:8.3f} "
              f"{fr.mean_wait['spjf'][g, 0]:8.3f} "
              f"{fr.mean_wait['sprpt'][g, 0]:8.3f}")

    print(f"\n{'sigma':>6} {'FIFO':>8} {'SPJF':>8} {'SPRPT':>8}"
          f"   (p99 wait, s)")
    for g, sg in enumerate(sigmas):
        print(f"{sg:6.2f} {fr.p99_wait['fifo'][0]:8.2f} "
              f"{fr.p99_wait['spjf'][g, 0]:8.2f} "
              f"{fr.p99_wait['sprpt'][g, 0]:8.2f}")

    for d in ("spjf", "sprpt"):
        xm = fifo_crossover_sigma(fr, d, "mean_wait")
        xp = fifo_crossover_sigma(fr, d, "p99_wait")
        fmt = lambda x: f"{x:.2f}" if np.isfinite(x) else "never"
        print(f"\n{d}: FIFO wins the mean at sigma = {fmt(xm)}, "
              f"the p99 tail at sigma = {fmt(xp)}")

    # a fitted (non-oracle) predictor: two-point classifier calibrated
    # from the synthetic data pipeline at the deployed budgets
    pred = calibrate_from_synthetic(prob, HEAVY, kind="two_point", seed=0)
    fr2 = sweep_prediction_error(prob, HEAVY, np.array([lam]),
                                 np.array([0.0, 0.5]), predictor=pred,
                                 n_seeds=8, n_queries=2000, seed=0)
    print(f"\ntwo-point predictor (boundaries={np.round(pred.boundaries, 2)}"
          f"): sprpt mean wait {fr2.mean_wait['sprpt'][0, 0]:.3f}s "
          f"noiseless vs oracle {fr.mean_wait['srpt'][0]:.3f}s")


if __name__ == "__main__":
    main()
