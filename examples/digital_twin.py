"""Closed-loop digital twin: learn the operating point, re-solve, serve.

Demonstrates the allocator<->engine loop with ZERO oracle operating-point
parameters: the controller starts from an uninformed uniform budget and
only ever sees the offline-calibrated accuracy curves — arrival rate,
mixture and the per-task latency curve are estimated online from the
stream it serves, with token budgets re-solved every control block via
the jitted grid solver.

Three acts:

1. stationary trace — watch the estimates and budgets converge onto the
   clairvoyant (oracle-parameter) solution;
2. drift — lambda triples mid-trace, then the mixture shifts; the loop
   tracks and re-allocates;
3. real decodes — wall-clock chunked-scan services on a reduced model
   drive the same Lindley twin, and the measured operating point is
   compared against the twin's own P-K prediction.

    PYTHONPATH=src python examples/digital_twin.py
"""
import numpy as np

from repro.core import paper_problem
from repro.core.allocator import solve
from repro.queueing_sim import Segment, generate_drift_trace
from repro.serving import ReplayConfig, ReplayHarness


def main():
    prob = paper_problem()
    lam = prob.server.lam
    oracle = np.asarray(solve(prob).lengths_int, dtype=np.int64)

    print("=== act 1: stationary trace, budgets converge to the oracle ===")
    trace = generate_drift_trace(prob.tasks, [Segment(30_000, lam)], seed=7)
    h = ReplayHarness(prob, ReplayConfig(block_size=512))
    res = h.run_virtual(trace)
    for b in res.blocks[:: max(1, len(res.blocks) // 8)]:
        e = b.estimator
        print(f"block {b.index:3d}  lam_hat={e['lam']:.4f}  "
              f"budgets={list(b.budgets)}")
    print(f"oracle (true lambda/pi/t0/c): {list(oracle)}")
    print(f"final (all learned online):   {list(res.final_budgets)}  "
          f"resolves={res.n_resolves}")
    m = res.measured()
    pred = h.predicted(lam)
    print(f"measured E[T_sys]={m['mean_system_time']:.3f}s "
          f"+-{m['ci95_system_time']:.3f}  "
          f"P-K predicted={pred['mean_system_time']:.3f}s")

    print("\n=== act 2: lambda x3 step, then mixture shift ===")
    n = prob.tasks.n_tasks
    pi_shift = np.full(n, 0.4 / (n - 1))
    pi_shift[1] = 0.6
    trace = generate_drift_trace(prob.tasks, [
        Segment(8000, lam),
        Segment(8000, 3 * lam),
        Segment(8000, lam, pi=tuple(pi_shift)),
    ], seed=13)
    res = ReplayHarness(prob, ReplayConfig(block_size=256,
                                           est_halflife=512.0)) \
        .run_virtual(trace)
    for b in res.blocks[:: max(1, len(res.blocks) // 12)]:
        e = b.estimator
        print(f"block {b.index:3d}  lam_hat={e['lam']:.4f}  "
              f"pi_hat[GSM8K]={e['pi'][1]:.2f}  "
              f"total_budget={int(b.budgets.sum())}")

    print("\n=== act 3: real chunked-scan decodes through the twin ===")
    import time

    import jax

    from repro.configs import get_config
    from repro.core import Problem, ServerParams
    from repro.models import init_params, reduced
    from repro.serving import DecodeEngine

    cfg = reduced(get_config("qwen3-0.6b"), d_model=128)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = DecodeEngine(cfg, params, cache_capacity=128, chunk=16)
    small = Problem(tasks=prob.tasks,
                    server=ServerParams(lam, 2.0, 48.0))
    rcfg = ReplayConfig(block_size=16, l_init=16, min_services=8,
                        explore_frac=0.25, explore_min_spread=8,
                        est_halflife=128.0)
    hh = ReplayHarness(small, rcfg, engine=eng)
    prompt = (np.arange(8) % 97 + 1).astype(np.int32)[None, :]
    eng.generate(prompt, [16], max_extra_tokens=0)          # compile
    t0 = time.perf_counter()
    eng.generate(prompt, [16], max_extra_tokens=0)
    lam_wall = 0.6 / (time.perf_counter() - t0)             # target rho 0.6
    wtrace = generate_drift_trace(prob.tasks, [Segment(128, lam_wall)],
                                  seed=17, prompt_len_range=(8, 8))
    res = hh.run_engine(wtrace, prompt_len=8)
    e = res.estimator_state
    m = res.measured(warmup_frac=0.25)
    print(f"{res.n} real decodes, {int(res.budgets.sum())} tokens; "
          f"budgets={list(res.final_budgets)}")
    print(f"learned latency curve: t0_hat={np.round(e['t0'], 4)} "
          f"c_hat={np.round(e['c'], 5)} s/token")
    print(f"measured E[T_sys]={m['mean_system_time'] * 1e3:.1f}ms, "
          f"twin P-K prediction={(e['pk_wait'] + e['es']) * 1e3:.1f}ms")


if __name__ == "__main__":
    main()
