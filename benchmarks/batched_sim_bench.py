"""Micro-benchmark: batched Lindley FIFO vs the legacy heapq event loop.

Workload is a fig4-style sensitivity cell: 16 seeds x 10k queries at the
paper's operating point, simulated under a stack of GSM8K-budget policies.
The acceptance bar for the batched subsystem is >= 20x wall-clock speedup
over running the scalar heapq DES over the same (seed x policy) grid; in
practice the numpy cumulative pass lands around three orders of magnitude.

    PYTHONPATH=src python -m benchmarks.batched_sim_bench [--smoke]

``--smoke`` shrinks the grid (4 seeds x 2k queries) and enforces a
wall-clock budget, for CI.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import paper_problem
from repro.queueing_sim import (generate_stream, generate_streams, simulate,
                                simulate_fifo_batch)

from .common import emit

LSTAR = np.array([0.0, 340.0, 0.0, 0.0, 345.0, 30.0])
GSM8K = 1


def _policy_stack() -> np.ndarray:
    """fig4-style: GSM8K budget swept with the other budgets at optimum."""
    policies = []
    for g in (0.0, 200.0, 340.0, 600.0, 1000.0):
        l = LSTAR.copy()
        l[GSM8K] = g
        policies.append(l)
    return np.stack(policies)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grid + wall-clock budget (CI)")
    ap.add_argument("--budget-s", type=float, default=60.0,
                    help="smoke-mode wall-clock budget for the batched path")
    args = ap.parse_args(argv)

    n_seeds, n_queries = (4, 2000) if args.smoke else (16, 10_000)
    prob = paper_problem()
    lam = prob.server.lam
    policies = _policy_stack()
    grid = policies.shape[0] * n_seeds * n_queries
    emit("batched_bench.grid", f"{policies.shape[0]}x{n_seeds}x{n_queries}",
         f"{grid} simulated queries")

    # --- legacy pipeline: scalar streams + one heapq DES call per cell -----
    t0 = time.perf_counter()
    streams = [generate_stream(prob.tasks, lam, n_queries, seed=i)
               for i in range(n_seeds)]
    ref_sys = np.array([[simulate(prob, l, s).mean_system_time
                         for s in streams] for l in policies])
    t_legacy = time.perf_counter() - t0
    t0 = time.perf_counter()
    _ = [simulate(prob, policies[0], s) for s in streams]
    t_heapq_row = time.perf_counter() - t0

    # --- batched pipeline: one RNG batch + one Lindley pass for the grid ---
    t0 = time.perf_counter()
    batch = generate_streams(prob.tasks, lam, n_seeds, n_queries, seed=100)
    stats = simulate_fifo_batch(prob, policies, batch, backend="numpy")
    t_numpy = time.perf_counter() - t0

    # --- jax scan backend (first call pays compile; report steady state) ---
    simulate_fifo_batch(prob, policies, batch, backend="jax")  # warmup
    t0 = time.perf_counter()
    stats_jax = simulate_fifo_batch(prob, policies, batch, backend="jax")
    t_jax = time.perf_counter() - t0

    # correctness anchors: both backends agree with each other to 1e-9, and
    # with the heapq DES statistically (different seeds, same law)
    np.testing.assert_allclose(stats.mean_system_time,
                               stats_jax.mean_system_time, atol=1e-9)
    rel = abs(stats.mean_system_time.mean() - ref_sys.mean()) / ref_sys.mean()
    assert rel < 0.25, f"batched and heapq pipelines disagree: {rel:.3f}"

    speedup_np = t_legacy / max(t_numpy, 1e-12)
    speedup_jax = t_legacy / max(t_jax, 1e-12)
    emit("batched_bench.legacy_s", f"{t_legacy:.3f}",
         "scalar streams + heapq DES over the grid")
    emit("batched_bench.heapq_sim_only_s", f"{t_heapq_row * len(policies):.3f}",
         "extrapolated DES-only time, excluding stream build")
    emit("batched_bench.numpy_s", f"{t_numpy:.4f}",
         f"end-to-end, speedup {speedup_np:.0f}x")
    emit("batched_bench.jax_s", f"{t_jax:.4f}",
         f"sim-only steady-state, speedup {speedup_jax:.0f}x")
    emit("batched_bench.qps_numpy", f"{grid / max(t_numpy, 1e-12):,.0f}",
         "simulated queries / wall-second")
    emit("batched_bench.speedup_ok", bool(speedup_np >= 20.0),
         "acceptance: >= 20x over the legacy pipeline")
    if not args.smoke:
        assert speedup_np >= 20.0, (
            f"batched path only {speedup_np:.1f}x faster than legacy")
    if args.smoke:
        assert t_numpy <= args.budget_s, (
            f"smoke budget blown: {t_numpy:.2f}s > {args.budget_s}s")


if __name__ == "__main__":
    main()
