"""Observability overhead + exactness benchmark (the obs/ gate).

Four lanes, all asserted in-bench and gated against the committed
``BENCH_obs.json`` by ``benchmarks/report.py --check``:

* **hist** — streaming-histogram ingest throughput (vectorized
  ``record_many`` updates/s) and percentile exactness: worst relative
  error vs exact ``numpy.percentile(method='inverted_cdf')`` across
  adversarial distributions (heavy-tail lognormal, bimodal, constant,
  uniform) must stay within the documented ``2**-bits`` bucket bound.
* **overhead.decode** — ``DecodeEngine.generate`` wall time with a
  ``Tracer`` attached (prefill + per-chunk dispatch spans, counted host
  syncs) vs detached. Full-run ceiling 3% (the tentpole contract);
  smoke ceiling is relaxed for shared CI runners.
* **overhead.des** — adaptive closed-loop ``ReplayHarness.run_virtual``
  (online estimators + cadence re-solves, the shape instrumented in
  production) with a ``MetricsRegistry`` folding wait/service/system-time
  histograms every block vs uninstrumented; metrics never feed the
  controller, so both runs execute identical control paths. Full-run
  ceiling 10%.
* **trace** — a closed-loop replay with the tracer attached must export
  a valid Chrome trace-event JSON whose span tree covers
  admit -> prefill -> decode -> retire for EVERY completed request
  (``obs.trace.validate_request_trees``); written to ``--trace-out`` so
  CI uploads an openable Perfetto artifact. The same lane checks the
  compile guards: one trace per jitted decode entry point across ragged
  budgets (``obs.jax_hooks.assert_max_compiles``).

    PYTHONPATH=src python -m benchmarks.obs_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import paper_problem
from repro.obs import (MetricsRegistry, StreamingHistogram, Tracer,
                       jax_hooks, validate_request_trees)
from repro.queueing_sim import Segment, generate_drift_trace
from repro.serving import ReplayConfig, ReplayHarness

from .common import emit, timed


# --------------------------------------------------------------------------
# Lane 1: histogram throughput + exactness
# --------------------------------------------------------------------------

def bench_hist(n_values: int, bits: int = 5) -> dict:
    rng = np.random.default_rng(0)
    values = rng.lognormal(0.0, 2.0, n_values)
    h = StreamingHistogram(bits=bits)
    _, us = timed(lambda: StreamingHistogram(bits=bits).record_many(values),
                  repeat=3, warmup=1, best=True)
    h.record_many(values)

    # exactness vs the order statistic on adversarial shapes
    dists = {
        "lognormal": values,
        "bimodal": np.concatenate([
            rng.normal(1.0, 0.05, n_values // 2).clip(1e-9),
            rng.normal(100.0, 5.0, n_values // 2)]),
        "constant": np.full(max(n_values // 4, 100), 3.7),
        "uniform": rng.uniform(0.0, 10.0, n_values),
    }
    bound = 2.0 ** -bits
    max_err = 0.0
    for name, x in dists.items():
        hx = StreamingHistogram(bits=bits)
        hx.record_many(x)
        for q in (50.0, 90.0, 99.0, 99.9):
            exact = float(np.percentile(x, q, method="inverted_cdf"))
            got = hx.percentile(q)
            err = abs(got - exact) / max(abs(exact), 1e-300)
            assert err <= bound + 1e-12, (
                f"{name} p{q}: rel err {err:.4f} > bound {bound:.4f} "
                f"(got {got}, exact {exact})")
            max_err = max(max_err, err)
    return {
        "n_values": n_values,
        "bits": bits,
        "updates_per_s": n_values / us * 1e6,
        "max_rel_err": max_err,
        "rel_err_bound": bound,
        "timing": us.stats,
    }


# --------------------------------------------------------------------------
# Lane 2: decode fast-path overhead (tracer attached vs detached)
# --------------------------------------------------------------------------

def bench_decode_overhead(repeat: int) -> dict:
    import jax

    from repro.configs import get_config
    from repro.models import init_params, reduced
    from repro.serving import DecodeEngine

    cfg = reduced(get_config("qwen3-0.6b"), d_model=128)
    params = init_params(cfg, jax.random.PRNGKey(0))
    # chunk < budget so the traced path emits several chunk spans + counted
    # host syncs per call — the worst realistic span density
    eng = DecodeEngine(cfg, params, cache_capacity=128, chunk=16)
    prompts = (np.arange(2 * 8).reshape(2, 8) % 97 + 1).astype(np.int32)
    budgets = [64, 64]

    def run():
        return eng.generate(prompts, budgets, max_extra_tokens=0)

    jax_hooks.reset()
    _, us_off = timed(run, repeat=repeat, warmup=1, best=True)
    eng.tracer = Tracer()
    _, us_on = timed(run, repeat=repeat, warmup=1, best=True)
    eng.tracer = None
    # one compile per decode entry point, tracer on or off: the wrapper
    # never perturbs the traced computation
    jax_hooks.assert_max_compiles("engine.prefill", 1)
    jax_hooks.assert_max_compiles("engine.scan", 1)
    frac = max(us_on.min / us_off.min - 1.0, 0.0)
    return {
        "decode_us_off": float(us_off),
        "decode_us_on": float(us_on),
        "frac": frac,
        "timing_off": us_off.stats,
        "timing_on": us_on.stats,
        "compiles": jax_hooks.trace_counts(),
        "transfers": jax_hooks.transfer_counts(),
    }


# --------------------------------------------------------------------------
# Lane 3: DES (closed-loop replay) overhead (metrics folding vs none)
# --------------------------------------------------------------------------

def bench_des_overhead(n_queries: int, repeat: int) -> dict:
    prob = paper_problem()
    trace = generate_drift_trace(prob.tasks, [Segment(n_queries, 0.25)],
                                 seed=11)
    cfg = ReplayConfig(block_size=4096)

    def run(with_metrics):
        # adaptive closed loop (estimators + cadence re-solves), the shape
        # instrumented in production; metrics folding never feeds the
        # controller, so both runs execute identical control paths
        reg = MetricsRegistry() if with_metrics else None
        h = ReplayHarness(prob, cfg, metrics=reg)
        return h.run_virtual(trace)

    _, us_off = timed(run, False, repeat=repeat, warmup=1, best=True)
    _, us_on = timed(run, True, repeat=repeat, warmup=1, best=True)
    frac = max(us_on.min / us_off.min - 1.0, 0.0)
    return {
        "n_queries": n_queries,
        "des_us_off": float(us_off),
        "des_us_on": float(us_on),
        "queries_per_s": n_queries / us_off * 1e6,
        "frac": frac,
        "timing_off": us_off.stats,
        "timing_on": us_on.stats,
    }


# --------------------------------------------------------------------------
# Lane 4: trace export validity + compile guards on ragged budgets
# --------------------------------------------------------------------------

def bench_trace_export(n_queries: int, trace_out: str | None) -> dict:
    prob = paper_problem()
    tr = Tracer()
    trace = generate_drift_trace(prob.tasks, [Segment(n_queries, 0.25)],
                                 seed=13)
    h = ReplayHarness(prob, ReplayConfig(block_size=512,
                                         resolve_mode="drift"), tracer=tr)
    res = h.run_virtual(trace)
    chrome = tr.to_chrome()
    info = validate_request_trees(chrome, range(trace.n))
    assert info["n_requests"] == n_queries
    out = {
        "n_requests": info["n_requests"],
        "n_events": info["n_events"],
        "n_resolves": res.n_resolves,
        "drift_checks": sum(1 for b in res.blocks if b.drift is not None),
    }
    if trace_out:
        out["path"] = tr.dump(trace_out)
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + relaxed ceilings (CI)")
    ap.add_argument("--budget-s", type=float, default=None,
                    help="smoke-mode wall-clock budget")
    ap.add_argument("--repeat", type=int, default=None,
                    help="timed calls per overhead lane")
    ap.add_argument("--json-out", default="BENCH_obs.json")
    ap.add_argument("--trace-out", default=None,
                    help="write the Perfetto trace JSON here (CI artifact)")
    args = ap.parse_args(argv)
    smoke = args.smoke
    repeat = args.repeat or (5 if smoke else 20)
    # ceilings: the tentpole contract on a quiet machine; relaxed on
    # shared CI runners where a background hiccup can exceed the margin
    decode_cap = 0.25 if smoke else 0.03
    des_cap = 0.40 if smoke else 0.10

    t_start = time.perf_counter()
    hist = bench_hist(200_000 if smoke else 2_000_000)
    emit("obs.hist.updates_per_s", f"{hist['updates_per_s']:.0f}",
         f"max_rel_err={hist['max_rel_err']:.4f} "
         f"(bound {hist['rel_err_bound']:.4f})")

    decode = bench_decode_overhead(repeat)
    emit("obs.overhead.decode_frac", f"{decode['frac']:.4f}",
         f"ceiling={decode_cap}, spans+counted syncs on the chunked scan")

    des = bench_des_overhead(50_000 if smoke else 400_000, repeat=3)
    emit("obs.overhead.des_frac", f"{des['frac']:.4f}",
         f"ceiling={des_cap}, histogram folding per control block")

    trace = bench_trace_export(2_000 if smoke else 10_000, args.trace_out)
    emit("obs.trace.n_events", str(trace["n_events"]),
         f"{trace['n_requests']} validated request trees, "
         f"{trace['n_resolves']} drift-mode resolves")
    wall_s = time.perf_counter() - t_start

    payload = {
        "mode": "smoke" if smoke else "full",
        "hist": hist,
        "overhead": {"decode_frac": decode["frac"],
                     "des_frac": des["frac"],
                     "decode": decode, "des": des,
                     "decode_cap": decode_cap, "des_cap": des_cap},
        "trace": trace,
        "compile": jax_hooks.snapshot(),
        "wall_s": wall_s,
    }
    with open(args.json_out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)

    assert decode["frac"] <= decode_cap, (
        f"decode-path instrumentation overhead {decode['frac']:.2%} "
        f"exceeds {decode_cap:.0%}")
    assert des["frac"] <= des_cap, (
        f"DES instrumentation overhead {des['frac']:.2%} "
        f"exceeds {des_cap:.0%}")
    if smoke and args.budget_s is not None:
        assert wall_s <= args.budget_s, (
            f"smoke bench took {wall_s:.1f}s > budget {args.budget_s}s")


if __name__ == "__main__":
    main()
