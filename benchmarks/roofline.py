"""Roofline report: reads the dry-run artifacts (results/dryrun +
results/roofline) and prints the per-(arch x shape) table of the three
terms. Run the sweeps first:

    python -m repro.launch.dryrun --all --mesh both --out results/dryrun
    python -m repro.launch.dryrun --all --mesh pod --roofline \
        --out results/roofline
"""
from __future__ import annotations

import glob
import json
import pathlib

from .common import emit

RESULTS = pathlib.Path("results")


def main() -> None:
    dr = sorted(glob.glob(str(RESULTS / "dryrun" / "*__dryrun*.json")))
    ok = fail = 0
    for f in dr:
        r = json.load(open(f))
        ok += bool(r.get("ok"))
        fail += not r.get("ok")
    emit("roofline.dryrun_combos_ok", ok, f"failed={fail}")

    rf = sorted(glob.glob(str(RESULTS / "roofline" / "*__roofline*.json")))
    if not rf:
        emit("roofline.note", "no-roofline-artifacts",
             "run the --roofline sweep first")
        return
    for f in rf:
        r = json.load(open(f))
        if not r.get("ok"):
            emit(f"roofline.{r['arch']}.{r['shape']}", "FAIL",
                 r.get("error", "")[:60])
            continue
        x = r["roofline"]
        key = f"{r['arch']}.{r['shape']}"
        emit(f"roofline.{key}.compute_s", f"{x['compute_s']:.3e}", "")
        emit(f"roofline.{key}.memory_s", f"{x['memory_s']:.3e}", "")
        emit(f"roofline.{key}.collective_s", f"{x['collective_s']:.3e}", "")
        emit(f"roofline.{key}.bottleneck", x["bottleneck"],
             f"model_flops_ratio={x['model_flops_ratio']:.3f}")


if __name__ == "__main__":
    main()
