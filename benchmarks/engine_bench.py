"""Engine microbenchmarks on CPU (reduced configs): decode step latency per
architecture family + kernel interpret-mode checks. Wall numbers are CPU
debug figures; the TPU roofline lives in benchmarks/roofline.py."""
from __future__ import annotations

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params, reduced
from repro.serving import DecodeEngine

from .common import emit, timed

ARCHS = ("qwen3-0.6b", "deepseek-moe-16b", "rwkv6-1.6b", "zamba2-7b")


def main() -> None:
    for arch in ARCHS:
        cfg = reduced(get_config(arch))
        params = init_params(cfg, jax.random.PRNGKey(0))
        eng = DecodeEngine(cfg, params, cache_capacity=256)
        prompts = np.ones((4, 16), dtype=np.int32)

        def gen():
            return eng.generate(prompts, [8, 8, 8, 8], max_extra_tokens=0)

        out, us = timed(gen, repeat=2)
        per_tok = us / (4 * 8)
        emit(f"engine.{arch}.decode_us_per_token", f"{per_tok:.0f}",
             "reduced cfg, CPU, batch=4")


if __name__ == "__main__":
    main()
