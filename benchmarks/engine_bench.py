"""Decode-throughput benchmark: chunked device-resident decode vs the
per-token reference loop, per architecture family.

The serving tentpole claim measured here: fusing generation into a chunked
``lax.scan`` (budget/EOS/alive masks carried as device state, KV cache
donated and updated in place via the static-layer decode path) beats the
per-token loop — one jitted dispatch + host sync + eager sample per token,
re-materializing capacity-sized cache leaves each step — by at least
``--min-speedup`` in tokens/s on the reduced-config CPU grid. Greedy
token-for-token equality between the two paths is asserted for EVERY
architecture measured (the continuous-batching exactness contract), so the
speedup is never bought with drift.

Timing uses ``common.timed`` with an untimed warmup call, so compile time
is excluded from every figure. Wall numbers are CPU debug figures; the TPU
roofline lives in benchmarks/roofline.py.

    PYTHONPATH=src python -m benchmarks.engine_bench [--smoke]

Either mode writes ``BENCH_engine.json`` (``--json-out`` to relocate) with
per-arch tokens/s, speedups, and the grid config. ``--smoke`` shrinks the
grid and relaxes the floor for noisy CI runners (the committed JSON comes
from a full run on a quiet machine, floor 5x). ``--kernel-check`` also
cross-checks the Pallas decode-attention slot path (interpret mode on CPU)
against the reference for token equality.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params, reduced
from repro.serving import DecodeEngine

from .common import emit, timed

ARCHS_FULL = ("qwen3-0.6b", "deepseek-moe-16b", "rwkv6-1.6b", "zamba2-7b")
ARCHS_SMOKE = ("qwen3-0.6b", "rwkv6-1.6b")

# grid where per-token dispatch+sync overhead and per-token cache
# re-materialization are both visible: tiny model, modest cache (with
# headroom for prompt + budget), 2 rows, chunk == budget so the fast path
# is a single dispatch per generate
GRID = dict(d_model=128, batch=2, budget=64, capacity=128, chunk=64,
            prompt_len=8)


def bench_arch(arch: str, repeat: int, grid: dict) -> dict:
    cfg = reduced(get_config(arch), d_model=grid["d_model"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = DecodeEngine(cfg, params, cache_capacity=grid["capacity"],
                       chunk=grid["chunk"])
    B, bud = grid["batch"], grid["budget"]
    prompts = (np.arange(B * grid["prompt_len"])
               .reshape(B, grid["prompt_len"]) % 97 + 1).astype(np.int32)
    budgets = [bud] * B

    def run(use_scan):
        return eng.generate(prompts, budgets, max_extra_tokens=0,
                            use_scan=use_scan)

    out_loop, us_loop = timed(run, False, repeat=repeat, best=True)
    out_scan, us_scan = timed(run, True, repeat=repeat, best=True)
    # exactness contract: the fast path must match the reference stream
    np.testing.assert_array_equal(out_loop["tokens"], out_scan["tokens"])
    np.testing.assert_array_equal(out_loop["n_generated"],
                                  out_scan["n_generated"])
    toks = B * bud
    return {
        "per_token_tok_s": toks / us_loop * 1e6,
        "chunked_tok_s": toks / us_scan * 1e6,
        "speedup": us_loop / us_scan,
        "greedy_equal": True,
        "decode_us_per_token_loop": us_loop / toks,
        "decode_us_per_token_scan": us_scan / toks,
        # full repeat-sample distributions (min/median/p95): variance
        # regressions are gateable, not just mean shifts
        "timing_loop": us_loop.stats,
        "timing_scan": us_scan.stats,
    }


def kernel_check(arch: str = "qwen3-0.6b") -> dict:
    """Greedy equality of the Pallas decode-attention slot path (interpret
    mode on CPU) vs the jnp reference, through the full engine."""
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    ref = DecodeEngine(cfg, params, cache_capacity=64, chunk=4)
    ker = DecodeEngine(cfg, params, cache_capacity=64, chunk=4,
                       use_decode_kernel=True)
    prompts = np.ones((2, 8), dtype=np.int32)
    o1 = ref.generate(prompts, [4, 6], max_extra_tokens=1)
    o2 = ker.generate(prompts, [4, 6], max_extra_tokens=1)
    np.testing.assert_array_equal(o1["tokens"], o2["tokens"])
    return {"arch": arch, "tokens_equal": True}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grid + relaxed floor + wall budget (CI)")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="required chunked-vs-per-token tokens/s speedup "
                         "(default: 5 full / 2 smoke)")
    ap.add_argument("--budget-s", type=float, default=None,
                    help="smoke-mode wall-clock budget")
    ap.add_argument("--repeat", type=int, default=5,
                    help="timed calls per path (fastest is reported)")
    ap.add_argument("--json-out", default="BENCH_engine.json")
    ap.add_argument("--kernel-check", action="store_true",
                    help="also cross-check the Pallas decode kernel path")
    args = ap.parse_args(argv)
    min_speedup = args.min_speedup
    if min_speedup is None:
        min_speedup = 2.0 if args.smoke else 5.0
    archs = ARCHS_SMOKE if args.smoke else ARCHS_FULL

    t_start = time.perf_counter()
    results = {}
    for arch in archs:
        r = bench_arch(arch, repeat=args.repeat, grid=GRID)
        results[arch] = r
        emit(f"engine.{arch}.chunked_tok_s", f"{r['chunked_tok_s']:.0f}",
             f"per_token={r['per_token_tok_s']:.0f}, "
             f"speedup={r['speedup']:.2f}x, greedy_equal")
        emit(f"engine.{arch}.decode_us_per_token",
             f"{r['decode_us_per_token_scan']:.0f}",
             f"loop={r['decode_us_per_token_loop']:.0f} "
             f"(reduced d={GRID['d_model']}, CPU, batch={GRID['batch']})")
    wall_s = time.perf_counter() - t_start

    kernel = None
    if args.kernel_check or not args.smoke:
        kernel = kernel_check()
        emit("engine.decode_kernel.tokens_equal", "1",
             "pallas slot path vs jnp reference, interpret mode")

    worst = min(r["speedup"] for r in results.values())
    payload = {
        "grid": GRID,
        "mode": "smoke" if args.smoke else "full",
        "min_speedup": min_speedup,
        "worst_speedup": worst,
        "wall_s": wall_s,
        "archs": results,
        "kernel_check": kernel,
    }
    with open(args.json_out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    emit("engine.worst_speedup", f"{worst:.2f}", f"floor={min_speedup}")

    assert worst >= min_speedup, (
        f"chunked decode speedup {worst:.2f}x below floor {min_speedup}x")
    if args.smoke and args.budget_s is not None:
        assert wall_s <= args.budget_s, (
            f"smoke bench took {wall_s:.1f}s > budget {args.budget_s}s")


if __name__ == "__main__":
    main()
