"""Discipline ablation: (lambda x discipline x policy) grid with CIs.

How much of the optimal allocation's gain survives when the server is not
FIFO? This benchmark sweeps the three disciplines (FIFO — the paper's
M/G/1 setting, eqs 3-6 — plus the beyond-paper SJF and marginal-utility
priority ablations) over an (arrival-rate x policy x seed) grid twice:

* **batched**: one ``sweep_disciplines`` call — the masked-argmin engine
  of ``queueing_sim.disciplines`` riding the shared Lindley/busy-period
  pass, all disciplines on common random numbers;
* **legacy**: the scalar pipeline this repo used before — one
  ``generate_stream`` per (rate, seed) and one heapq ``mg1.simulate`` per
  grid cell.

Both produce the same table (the per-cell agreement of the two paths is
pinned by ``tests/test_disciplines.py`` at ~1e-10 per query; here the
stream seeds differ, so cells are compared statistically). The headline
is throughput: the batched path must clear ``--min-speedup`` (default
20x on the smoke grid, mirroring the FIFO fast path's acceptance bar; the
full grid adds a rho=0.8 heavy-traffic row whose longer busy periods cost
the engine more, so its default floor is 10x).

    PYTHONPATH=src python -m benchmarks.discipline_ablation [--smoke]

Either mode writes a ``BENCH_disciplines.json`` artifact (``--json-out``
to relocate) with the full ablation table, overflow diagnostics, and the
timing trajectory. ``--smoke`` shrinks the grid and enforces a
wall-clock budget, for CI.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import paper_problem
from repro.queueing_sim import (DISCIPLINES, generate_stream, simulate,
                                simulate_batch, sweep_disciplines)

from .common import emit

LSTAR = np.array([0.0, 340.0, 0.0, 0.0, 345.0, 30.0])  # ~ paper Table I l*


def _grid(prob, smoke: bool):
    """Arrival rates from target utilizations of the uniform-300 policy."""
    t = np.asarray(prob.tasks.t0) + np.asarray(prob.tasks.c) * 300.0
    es300 = float(np.sum(np.asarray(prob.tasks.pi) * t))
    if smoke:
        rhos = (0.45, 0.6)
        n_seeds, n_queries = 96, 500
    else:
        rhos = (0.5, 0.65, 0.8)
        n_seeds, n_queries = 16, 10_000
    lams = [r / es300 for r in rhos]
    policies = {
        "optimal": LSTAR,
        "uniform_100": np.full(6, 100.0),
        "uniform_300": np.full(6, 300.0),
    }
    return rhos, lams, policies, n_seeds, n_queries


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grid + wall-clock budget (CI)")
    ap.add_argument("--budget-s", type=float, default=60.0,
                    help="smoke-mode wall-clock budget for the batched path")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="required batched-vs-heapq speedup "
                         "(default: 20 smoke / 10 full)")
    ap.add_argument("--json-out", default="BENCH_disciplines.json",
                    help="perf-trajectory artifact path")
    args = ap.parse_args(argv)
    min_speedup = args.min_speedup
    if min_speedup is None:
        min_speedup = 20.0 if args.smoke else 10.0

    prob = paper_problem()
    rhos, lams, policies, n_seeds, n_queries = _grid(prob, args.smoke)
    P, D, Lg = len(policies), len(DISCIPLINES), len(lams)
    cells = Lg * D * P
    grid_queries = cells * n_seeds * n_queries
    emit("disciplines.grid", f"{Lg}x{D}x{P}x{n_seeds}x{n_queries}",
         f"{grid_queries} simulated queries, rho(u300)={rhos}")

    # --- batched pipeline: sweep_disciplines (steady state, best of 4) ----
    res = sweep_disciplines(prob, policies, lams, n_seeds=n_seeds,
                            n_queries=n_queries, seed=0)  # warm jit caches
    t_batched = np.inf
    for _ in range(4):
        t0 = time.perf_counter()
        res = sweep_disciplines(prob, policies, lams, n_seeds=n_seeds,
                                n_queries=n_queries, seed=0)
        t_batched = min(t_batched, time.perf_counter() - t0)

    # --- legacy pipeline: scalar streams + one heapq DES per cell ---------
    # (also steady-state: best of 2, symmetric with the batched timing)
    t_legacy = np.inf
    for _ in range(2):
        t0 = time.perf_counter()
        legacy_sys = np.empty((Lg, D, P))
        for i, lam in enumerate(lams):
            streams = [generate_stream(prob.tasks, lam, n_queries, seed=s)
                       for s in range(n_seeds)]
            for d, disc in enumerate(DISCIPLINES):
                for p, budgets in enumerate(policies.values()):
                    lengths = res[disc].lengths[i, p]  # same clipped budgets
                    legacy_sys[i, d, p] = np.mean(
                        [simulate(prob, lengths, st,
                                  discipline=disc).mean_system_time
                         for st in streams])
        t_legacy = min(t_legacy, time.perf_counter() - t0)
    speedup = t_legacy / max(t_batched, 1e-12)

    # correctness anchors: the two pipelines sample the same law (different
    # stream seeds), so cell means must agree statistically; and on ONE
    # shared batch the engine must reproduce the heapq DES to float noise.
    for d, disc in enumerate(DISCIPLINES):
        rel = np.abs(legacy_sys[:, d, :] - res[disc].mean_system_time)
        rel /= np.maximum(res[disc].mean_system_time, 1e-9)
        assert np.all(rel < 0.25), f"{disc}: pipelines disagree ({rel})"
    from repro.queueing_sim import generate_streams
    anchor = generate_streams(prob.tasks, lams[-1], 2, min(n_queries, 2000),
                              seed=123)
    for disc in ("sjf", "priority"):
        fast = simulate_batch(prob, LSTAR, anchor, discipline=disc)
        ref = [simulate(prob, LSTAR, anchor.stream(s), discipline=disc)
               for s in range(2)]
        err = max(abs(fast.mean_system_time[s] - ref[s].mean_system_time)
                  for s in range(2))
        assert err < 1e-9, f"{disc} anchor err {err}"
    emit("disciplines.anchor", "ok",
         "engine == heapq on shared streams (1e-9); pipelines agree <25%")

    # --- ablation table ---------------------------------------------------
    table = []
    fifo = res["fifo"]
    for i, (rho, lam) in enumerate(zip(rhos, lams)):
        for disc in DISCIPLINES:
            r = res[disc]
            for p, name in enumerate(r.policy_names):
                table.append({
                    "rho_u300": rho, "lam": lam, "discipline": disc,
                    "policy": name,
                    "rho_analytic": float(r.rho_analytic[i, p]),
                    "mean_wait": float(r.mean_wait[i, p]),
                    "mean_system_time": float(r.mean_system_time[i, p]),
                    "ci_system_time": float(r.ci_system_time[i, p]),
                    "objective": float(r.objective[i, p]),
                    "ci_objective": float(r.ci_objective[i, p]),
                    "wait_vs_fifo": float(r.mean_wait[i, p]
                                          - fifo.mean_wait[i, p]),
                    "overflow_frac": float(r.overflow_frac[i, p]),
                })
    for disc in ("sjf", "priority"):
        gain = fifo.mean_wait - res[disc].mean_wait
        emit(f"disciplines.wait_cut.{disc}",
             f"{float(gain.max()):.3f}",
             "max mean-wait reduction vs FIFO (s), CRN-paired")
    # SJF must never wait longer than FIFO on paired streams
    assert np.all(res["sjf"].mean_wait <= fifo.mean_wait + 1e-9)

    qps = grid_queries / max(t_batched, 1e-12)
    emit("disciplines.legacy_s", f"{t_legacy:.2f}",
         "scalar streams + heapq DES over the grid")
    emit("disciplines.batched_s", f"{t_batched:.3f}",
         f"sweep_disciplines steady state, speedup {speedup:.0f}x")
    emit("disciplines.qps", f"{qps:,.0f}", "simulated queries / wall-second")
    emit("disciplines.speedup_ok", bool(speedup >= min_speedup),
         f"acceptance: >= {min_speedup:.0f}x over the heapq loop")

    payload = {
        "mode": "smoke" if args.smoke else "full",
        "grid": {"rhos_u300": list(rhos), "lams": list(map(float, lams)),
                 "policies": {k: list(map(float, v))
                              for k, v in policies.items()},
                 "disciplines": list(DISCIPLINES),
                 "n_seeds": n_seeds, "n_queries": n_queries},
        "timings": {"legacy_s": t_legacy, "batched_s": t_batched,
                    "speedup": speedup, "queries_per_s": qps,
                    "min_speedup": min_speedup},
        "cells": table,
    }
    with open(args.json_out, "w") as fh:
        json.dump(payload, fh, indent=1)
    emit("disciplines.json", args.json_out, "ablation artifact written")

    if args.smoke:
        assert t_batched <= args.budget_s, (
            f"smoke budget blown: {t_batched:.2f}s > {args.budget_s}s")
    assert speedup >= min_speedup, (
        f"batched path only {speedup:.1f}x faster than the heapq loop "
        f"(need {min_speedup:.0f}x)")


if __name__ == "__main__":
    main()
