"""Shared benchmark utilities: CSV emission + timing.

``timed`` records EVERY repeat sample, not just the summary scalar: the
returned value is a :class:`TimedUS` float (min or mean, unchanged
contract — call sites keep doing arithmetic on it) that additionally
carries ``samples``/``min``/``median``/``p95`` and a JSON-able ``stats``
dict, so ``BENCH_*.json`` artifacts can gate variance regressions (a p95
blow-up on a stable min), not only mean shifts.
"""
from __future__ import annotations

import statistics


def emit(name: str, value, derived: str = "") -> None:
    print(f"{name},{value},{derived}", flush=True)


class TimedUS(float):
    """Per-call microseconds with the full repeat-sample distribution.

    Behaves exactly like the float ``timed`` used to return (min if
    ``best`` else mean); the per-sample attributes ride along for
    reporting.
    """

    samples: tuple
    min: float
    median: float
    p95: float
    mean: float

    def __new__(cls, value: float, samples_us):
        self = super().__new__(cls, value)
        s = sorted(float(v) for v in samples_us)
        self.samples = tuple(s)
        self.min = s[0]
        self.median = statistics.median(s)
        # nearest-rank p95: the worst sample until repeat >= 20
        self.p95 = s[min(len(s) - 1, max(0, -(-len(s) * 95 // 100) - 1))]
        self.mean = statistics.fmean(s)
        return self

    @property
    def stats(self) -> dict:
        """JSON-able summary for ``BENCH_*.json`` timing entries."""
        return {"min_us": self.min, "median_us": self.median,
                "p95_us": self.p95, "mean_us": self.mean,
                "n_samples": len(self.samples)}


def timed(fn, *args, repeat: int = 3, warmup: int = 1, best: bool = False,
          **kwargs):
    """Returns (result, microseconds per call) — a :class:`TimedUS`.

    ``warmup`` untimed calls run first so jit compilation (and any
    first-call cache/tracing work) is excluded from the timed repeats —
    per-call figures like ``decode_us_per_token`` must never average in
    compile time. ``best=True`` reports the FASTEST repeat instead of the
    mean (the standard microbenchmark estimator: rejects scheduler noise
    on shared/small machines instead of averaging it in); either way the
    full sample list is preserved on the returned value.
    """
    from repro.obs.trace import monotonic

    for _ in range(max(warmup, 0)):
        out = fn(*args, **kwargs)
    times = []
    for _ in range(repeat):
        t0 = monotonic()
        out = fn(*args, **kwargs)
        times.append(monotonic() - t0)
    us = (min(times) if best else sum(times) / len(times)) * 1e6
    return out, TimedUS(us, [t * 1e6 for t in times])
