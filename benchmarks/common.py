"""Shared benchmark utilities: CSV emission + timing."""
from __future__ import annotations

import time


def emit(name: str, value, derived: str = "") -> None:
    print(f"{name},{value},{derived}", flush=True)


def timed(fn, *args, repeat: int = 3, warmup: int = 1, best: bool = False,
          **kwargs):
    """Returns (result, microseconds per call).

    ``warmup`` untimed calls run first so jit compilation (and any
    first-call cache/tracing work) is excluded from the timed repeats —
    per-call figures like ``decode_us_per_token`` must never average in
    compile time. ``best=True`` reports the FASTEST repeat instead of the
    mean (the standard microbenchmark estimator: rejects scheduler noise
    on shared/small machines instead of averaging it in).
    """
    for _ in range(max(warmup, 0)):
        out = fn(*args, **kwargs)
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        times.append(time.perf_counter() - t0)
    us = (min(times) if best else sum(times) / len(times)) * 1e6
    return out, us
