"""Shared benchmark utilities: CSV emission + timing."""
from __future__ import annotations

import time


def emit(name: str, value, derived: str = "") -> None:
    print(f"{name},{value},{derived}", flush=True)


def timed(fn, *args, repeat: int = 3, **kwargs):
    """Returns (result, microseconds per call)."""
    fn(*args, **kwargs)          # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kwargs)
    us = (time.perf_counter() - t0) / repeat * 1e6
    return out, us
