"""Paper Table I: optimal reasoning-token allocation on the calibrated
Qwen3-8B instance (lam=0.1, alpha=30, l_max=32768, pi=1/6)."""
from __future__ import annotations

import numpy as np

from repro.core import (PAPER_TABLE1_LSTAR, paper_problem, solve,
                        solve_fixed_point, solve_pga_backtracking)

from .common import emit, timed
from repro.compat import enable_x64


def main() -> None:
    prob = paper_problem()
    sol, us = timed(lambda: solve(prob), repeat=3)
    names = prob.tasks.names
    paper = np.asarray(PAPER_TABLE1_LSTAR)
    for i, n in enumerate(names):
        emit(f"table1.lstar.{n}", f"{sol.lengths_cont[i]:.1f}",
             f"paper={paper[i]:.1f}")
        emit(f"table1.lint.{n}", int(sol.lengths_int[i]), "")
    err = float(np.max(np.abs(sol.lengths_cont - paper)))
    emit("table1.solve", f"{us:.0f}", f"max_abs_dev_vs_paper={err:.2f}")
    emit("table1.J_continuous", f"{sol.value_cont:.6f}", "")
    emit("table1.J_integer", f"{sol.value_int:.6f}", "")
    emit("table1.J_lower_bound", f"{sol.value_lower_bound:.6f}", "eq41")
    emit("table1.method", sol.method, f"iters={sol.iterations}")

    import jax
    with enable_x64():
        _, us_fp = timed(lambda: solve_fixed_point(prob).lengths.block_until_ready())
        _, us_pga = timed(lambda: solve_pga_backtracking(prob)
                          .lengths.block_until_ready())
    emit("table1.fixed_point", f"{us_fp:.0f}", "us_per_solve")
    emit("table1.pga_backtracking", f"{us_pga:.0f}", "us_per_solve")


if __name__ == "__main__":
    main()
