"""Paper Table I: optimal reasoning-token allocation on the calibrated
Qwen3-8B instance (lam=0.1, alpha=30, l_max=32768, pi=1/6).

The table is produced by the vmapped grid solver (one-cell grid); the
scalar facade is re-run as the reference implementation and must agree
bitwise-tight (continuous to 1e-6, identical integers)."""
from __future__ import annotations

import numpy as np

from repro.core import (PAPER_TABLE1_LSTAR, paper_problem, solve,
                        solve_fixed_point, solve_pga_backtracking)
from repro.sweeps import reference_check, solve_grid

from .common import emit, timed
from repro.compat import enable_x64


def main() -> None:
    prob = paper_problem()
    sp = prob.server
    gsol, us_grid = timed(
        lambda: solve_grid(prob.tasks, sp.lam, sp.alpha, sp.l_max), repeat=3)
    sol, us = timed(lambda: solve(prob), repeat=3)
    agree = reference_check(prob.tasks, gsol)
    emit("table1.grid_vs_scalar_lstar", f"{agree:.2e}",
         "grid path vs reference scalar solve")
    names = prob.tasks.names
    paper = np.asarray(PAPER_TABLE1_LSTAR)
    for i, n in enumerate(names):
        emit(f"table1.lstar.{n}", f"{gsol.lengths_cont[i]:.1f}",
             f"paper={paper[i]:.1f}")
        emit(f"table1.lint.{n}", int(gsol.lengths_int[i]), "")
    err = float(np.max(np.abs(sol.lengths_cont - paper)))
    emit("table1.solve", f"{us:.0f}", f"max_abs_dev_vs_paper={err:.2f}")
    emit("table1.solve_grid_1cell", f"{us_grid:.0f}",
         "us per one-cell grid solve (incl. retrace)")
    emit("table1.J_continuous", f"{sol.value_cont:.6f}", "")
    emit("table1.J_integer", f"{sol.value_int:.6f}", "")
    emit("table1.J_lower_bound", f"{sol.value_lower_bound:.6f}", "eq41")
    emit("table1.method", sol.method, f"iters={sol.iterations}")

    import jax
    with enable_x64():
        _, us_fp = timed(lambda: solve_fixed_point(prob).lengths.block_until_ready())
        _, us_pga = timed(lambda: solve_pga_backtracking(prob)
                          .lengths.block_until_ready())
    emit("table1.fixed_point", f"{us_fp:.0f}", "us_per_solve")
    emit("table1.pga_backtracking", f"{us_pga:.0f}", "us_per_solve")


if __name__ == "__main__":
    main()
