"""Paper Fig 2: accuracy-curve calibration quality.

We regenerate noisy samples from the Table I curves (the paper's raw
measurements are not published) and verify the calibration pipeline
recovers curves that match pointwise."""
from __future__ import annotations

import numpy as np

from repro.core import paper_tasks
from repro.core.calibration import calibrate_taskset

from .common import emit


def main() -> None:
    tasks = paper_tasks()
    budgets = np.array([0, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096,
                        8192, 16384])
    rng = np.random.default_rng(0)
    A, b, D = (np.asarray(t) for t in (tasks.A, tasks.b, tasks.D))
    t0, c = np.asarray(tasks.t0), np.asarray(tasks.c)
    acc = A[:, None] * (1 - np.exp(-b[:, None] * budgets[None])) + D[:, None]
    acc_noisy = np.clip(acc + rng.normal(0, 0.01, acc.shape), 0, 1)
    lat = t0[:, None] + c[:, None] * budgets[None]
    lat_noisy = lat * (1 + rng.normal(0, 0.01, lat.shape))
    refit = calibrate_taskset(tasks.names, budgets, acc_noisy, lat_noisy)
    rA, rb, rD = (np.asarray(t) for t in (refit.A, refit.b, refit.D))
    racc = rA[:, None] * (1 - np.exp(-rb[:, None] * budgets[None])) + rD[:, None]
    for i, n in enumerate(tasks.names):
        rmse = float(np.sqrt(np.mean((racc[i] - acc[i]) ** 2)))
        emit(f"fig2.curve_rmse.{n}", f"{rmse:.4f}",
             f"b_true={b[i]:.2e},b_fit={rb[i]:.2e}")
    lat_err = float(np.max(np.abs(np.asarray(refit.c) - c) / c))
    emit("fig2.latency_c_max_rel_err", f"{lat_err:.4f}", "")


if __name__ == "__main__":
    main()
