"""Prediction-aware scheduling: zero-error pins + the robustness frontier.

The predicted disciplines (SPJF/SPRPT, ``queueing_sim.disciplines``) only
earn their keep if (a) at zero prediction error they are *exactly* the
known-size SJF/SRPT schedulers — pinned here bitwise on the NumPy and JAX
lanes against the heapq oracles — and (b) their advantage over size-blind
FIFO degrades gracefully as prediction error grows. This benchmark runs
both checks and produces the robustness frontier
(``sweeps.sweep_prediction_error``) on a heavy-tailed operating point
(all reasoning budget on one task, service CV^2 ~ 4.7), where the
documented structure is:

* the **mean-wait** advantage of SPJF/SPRPT over FIFO survives every
  error level swept (with CV^2 > 1, even size-blind preemption beats
  FIFO in the mean);
* the **p99-wait** advantage dies at a finite error level: SPRPT's tail
  crosses FIFO at sigma ~ 0.3-0.7 (underestimated long jobs monopolize
  the server; short jobs queue behind them), the headline
  ``fifo_crossover_sigma`` gated in CI against this artifact.

The frontier's FIFO/SJF/SRPT reference lanes are cross-checked against
``sweep_disciplines`` (the batched discipline engine) on common random
numbers to float noise — same streams, two independent drivers.

    PYTHONPATH=src python -m benchmarks.prediction_bench [--smoke]

Either mode writes ``BENCH_prediction.json`` (``--json-out`` to
relocate); ``--smoke`` shrinks the grid and enforces a wall-clock
budget, for CI (gated by ``benchmarks.report --check``).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import paper_problem
from repro.data.predictor import LengthPredictor
from repro.queueing_sim import generate_streams, sweep_disciplines
from repro.queueing_sim.disciplines import (discipline_keys,
                                            sprpt_start_finish,
                                            srpt_start_finish,
                                            windowed_start_finish)
from repro.queueing_sim.mg1 import (event_loop, sprpt_event_loop,
                                    srpt_event_loop)
from repro.sweeps.prediction import (fifo_crossover_sigma, service_cv2,
                                     sweep_prediction_error)

from .common import emit

# heavy-tailed operating point: the whole reasoning budget on one task
# (CV^2 ~ 4.7) — the regime where size-based scheduling wins the tail at
# zero error, so the error level that *loses* the tail is identifiable
HEAVY = np.array([2000.0, 0.0, 0.0, 0.0, 0.0, 0.0])


def _grid(smoke: bool):
    if smoke:
        sigmas = np.array([0.0, 0.3, 0.6, 1.0, 2.0])
        rhos = (0.8,)
        n_seeds, n_queries = 8, 1500
    else:
        sigmas = np.array([0.0, 0.2, 0.4, 0.6, 0.8, 1.0, 1.5, 2.0, 2.5, 3.0])
        rhos = (0.5, 0.8)
        n_seeds, n_queries = 16, 4000
    return sigmas, rhos, n_seeds, n_queries


def _zero_error_pins(prob, n: int = 1200) -> None:
    """SPJF==SJF and SPRPT==SRPT bitwise at zero error, every lane."""
    batch = generate_streams(prob.tasks, 0.19, 2, n, seed=7)
    t = prob.tasks
    svc = (np.asarray(t.t0) + np.asarray(t.c) * HEAVY)[batch.types]
    arr = batch.arrivals
    oracle = LengthPredictor().predict(svc)          # bitwise identity
    k_sjf = discipline_keys("sjf", services=svc)
    k_spjf = discipline_keys("spjf", services=svc, predicted=oracle)
    for backend in ("numpy", "jax"):
        st1, f1, _ = windowed_start_finish(arr, svc, k_sjf, backend=backend)
        st2, f2, _ = windowed_start_finish(arr, svc, k_spjf, backend=backend)
        assert np.array_equal(f1, f2) and np.array_equal(st1, st2), (
            f"spjf != sjf bitwise at zero error ({backend} lane)")
    _, f_srpt, _ = srpt_start_finish(arr, svc)
    _, f_sprpt, _ = sprpt_start_finish(arr, svc, oracle)
    assert np.array_equal(f_srpt, f_sprpt), \
        "sprpt != srpt bitwise at zero error (panel kernel)"
    # heapq oracles: kernels vs event loops per stream, and the zero-error
    # event-loop identity itself
    for s in range(batch.n_seeds):
        a_s, s_s = arr[s], svc[s]
        assert np.array_equal(srpt_event_loop(a_s, s_s),
                              sprpt_event_loop(a_s, s_s, s_s.copy())), \
            "sprpt_event_loop != srpt_event_loop at zero error"
        _, f_ref = event_loop(a_s, s_s, s_s)
        assert np.abs(f1[s] - f_ref).max() < 1e-9, "sjf lane vs heapq"
        assert np.abs(f_sprpt[s]
                      - sprpt_event_loop(a_s, s_s, s_s.copy())).max() < 1e-9
    emit("prediction.zero_error_pins", "ok",
         "spjf==sjf, sprpt==srpt bitwise (numpy+jax lanes, heapq oracles)")


def _crn_crosscheck(prob, lams, n_seeds, n_queries) -> float:
    """Frontier reference lanes vs sweep_disciplines on the same streams."""
    fr = sweep_prediction_error(prob, HEAVY, lams, np.array([0.0]),
                                n_seeds=n_seeds, n_queries=n_queries, seed=0)
    res = sweep_disciplines(prob, {"heavy": HEAVY}, lams,
                            disciplines=("fifo", "sjf", "srpt"),
                            n_seeds=n_seeds, n_queries=n_queries, seed=0,
                            clip_unstable=False)
    worst = 0.0
    for d in ("fifo", "sjf", "srpt"):
        a = fr.mean_wait[d]
        b = res[d].mean_wait[:, 0]
        worst = max(worst, float(np.max(np.abs(a - b)
                                        / np.maximum(np.abs(b), 1e-12))))
    assert worst < 1e-8, f"frontier vs sweep_disciplines CRN gap {worst}"
    return worst


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grid + wall-clock budget (CI)")
    ap.add_argument("--budget-s", type=float, default=60.0,
                    help="smoke-mode wall-clock budget for the frontier")
    ap.add_argument("--json-out", default="BENCH_prediction.json",
                    help="frontier artifact path")
    args = ap.parse_args(argv)

    prob = paper_problem()
    sigmas, rhos, n_seeds, n_queries = _grid(args.smoke)
    t = np.asarray(prob.tasks.t0) + np.asarray(prob.tasks.c) * HEAVY
    es = float(np.sum(np.asarray(prob.tasks.pi) * t))
    lams = np.array([r / es for r in rhos])
    cv2 = service_cv2(prob, HEAVY)
    emit("prediction.grid",
         f"{len(lams)}x{len(sigmas)}x{n_seeds}x{n_queries}",
         f"rho={rhos}, cv2={cv2:.2f}")

    _zero_error_pins(prob)
    crn_gap = _crn_crosscheck(prob, lams, min(n_seeds, 8),
                              min(n_queries, 2000))
    emit("prediction.crn_gap", f"{crn_gap:.2e}",
         "frontier refs vs sweep_disciplines, common random numbers")

    # --- the frontier (steady state, best of 2) ---------------------------
    run = lambda: sweep_prediction_error(prob, HEAVY, lams, sigmas,
                                         n_seeds=n_seeds,
                                         n_queries=n_queries, seed=0)
    fr = run()  # warm caches
    t_frontier = np.inf
    for _ in range(2):
        t0 = time.perf_counter()
        fr = run()
        t_frontier = min(t_frontier, time.perf_counter() - t0)
    # lanes simulated: fifo + sjf + srpt references + G spjf + G sprpt
    lanes = 3 + 2 * len(sigmas)
    grid_queries = len(lams) * lanes * n_seeds * n_queries
    qps = grid_queries / max(t_frontier, 1e-12)

    # frontier structure at the heaviest load (last lambda):
    xover = {
        "sprpt_p99": fifo_crossover_sigma(fr, "sprpt", "p99_wait", -1),
        "spjf_p99": fifo_crossover_sigma(fr, "spjf", "p99_wait", -1),
        "sprpt_mean": fifo_crossover_sigma(fr, "sprpt", "mean_wait", -1),
        "spjf_mean": fifo_crossover_sigma(fr, "spjf", "mean_wait", -1),
    }
    # (a) at zero error the frontier's left edge IS the reference lane
    assert np.array_equal(fr.mean_wait["spjf"][0], fr.mean_wait["sjf"])
    assert np.array_equal(fr.mean_wait["sprpt"][0], fr.mean_wait["srpt"])
    # (b) the SPRPT tail crossover is finite and in the documented band:
    # prediction error costs the tail long before it costs the mean
    assert np.isfinite(xover["sprpt_p99"]), \
        "no FIFO p99 crossover found for sprpt — frontier structure lost"
    assert 0.05 < xover["sprpt_p99"] < 2.5, \
        f"sprpt p99 crossover {xover['sprpt_p99']:.3f} outside [0.05, 2.5]"
    # (c) the mean-wait advantage survives the whole sweep (CV^2 > 1)
    assert np.all(fr.mean_wait["spjf"] < fr.mean_wait["fifo"][None, :]), \
        "spjf mean wait crossed FIFO on a CV^2>1 workload"
    assert np.all(fr.mean_wait["sprpt"] < fr.mean_wait["fifo"][None, :]), \
        "sprpt mean wait crossed FIFO on a CV^2>1 workload"
    emit("prediction.crossover.sprpt_p99", f"{xover['sprpt_p99']:.3f}",
         "error level where SPRPT's tail advantage over FIFO dies")
    emit("prediction.mean_advantage", "ok",
         "SPJF/SPRPT mean wait < FIFO at every swept sigma")
    emit("prediction.frontier_s", f"{t_frontier:.3f}",
         f"{grid_queries} simulated queries, {qps:,.0f}/s")

    payload = {
        "mode": "smoke" if args.smoke else "full",
        "grid": {"rhos": list(rhos), "lams": lams.tolist(),
                 "sigmas": sigmas.tolist(), "lengths": HEAVY.tolist(),
                 "cv2": cv2, "n_seeds": n_seeds, "n_queries": n_queries},
        "crossover": {k: (v if np.isfinite(v) else None)
                      for k, v in xover.items()},
        "crn_gap": crn_gap,
        "timings": {"frontier_s": t_frontier, "queries_per_s": qps},
        "frontier": fr.summary(),
    }
    with open(args.json_out, "w") as fh:
        json.dump(payload, fh, indent=1)
    emit("prediction.json", args.json_out, "frontier artifact written")

    if args.smoke:
        assert t_frontier <= args.budget_s, (
            f"smoke budget blown: {t_frontier:.2f}s > {args.budget_s}s")


if __name__ == "__main__":
    main()
