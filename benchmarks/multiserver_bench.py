"""Multi-server + preemptive queueing benchmark: M/G/c and SRPT fast paths.

Two throughput lanes, each against its scalar heapq reference, plus the
Erlang-C/Lee-Longton validation grid:

* **M/G/c**: the batched next-free-server kernel
  (``queueing_sim.multiserver.free_server_numpy``) sweeps a whole
  (c x rho x policy x seed) panel in one call; the legacy path runs one
  ``mg1.event_loop_mgc`` heapq loop per stream. Per-query agreement with
  the heapq oracle is asserted at 1e-9 on an anchor batch, and every
  (c, rho) cell's mean wait must fall within the DES 95% CI plus the
  documented Lee-Longton allowance (``core.mgc``: heavy-traffic exact,
  up to ~15% under-prediction at moderate load) of the analytic
  prediction — the per-cell relative errors are recorded in the artifact.
* **SRPT**: the preemptive ring kernel (``disciplines.srpt_numpy``)
  against one ``mg1.srpt_event_loop`` per stream, pinned per query at
  1e-9, with the pathwise-optimality check (SRPT mean system time never
  above FIFO's on paired streams).

    PYTHONPATH=src python -m benchmarks.multiserver_bench [--smoke]

Either mode writes ``BENCH_multiserver.json`` (``--json-out`` to
relocate) with the validation grid, timings, and speedups. ``--smoke``
shrinks the grid and enforces a wall-clock budget for CI; like the other
smoke lanes, its speedup floor is relaxed relative to the committed
full-run numbers (shared runners are noisy and the smoke grid amortizes
less Python-loop overhead per batched step).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import paper_problem
from repro.core.mgc import mgc_wait_np
from repro.queueing_sim import (event_loop_mgc, free_server_numpy,
                                generate_streams, srpt_event_loop,
                                srpt_numpy)
from repro.queueing_sim.batched import _service_table, lindley_numpy
from repro.queueing_sim.stats import ci95

from .common import emit

LSTAR = np.array([0.0, 340.0, 0.0, 0.0, 345.0, 30.0])  # ~ paper Table I l*

#: Documented Lee-Longton allowance by load regime (see ``core.mgc``).
LL_RTOL = {0.6: 0.15, 0.9: 0.05}


def _grid(smoke: bool):
    cs = (2, 4)
    rhos = (0.6, 0.9)
    if smoke:
        # streams must still be long enough for the rho = 0.9 cells to mix
        # past the finite-horizon bias, or the validation gate is testing
        # warmup error instead of the approximation
        n_seeds, n_queries, warm_frac = 16, 5000, 0.3
    else:
        n_seeds, n_queries, warm_frac = 16, 10_000, 0.25
    return cs, rhos, n_seeds, n_queries, warm_frac


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grid + wall-clock budget (CI)")
    ap.add_argument("--budget-s", type=float, default=60.0,
                    help="smoke-mode wall-clock budget for the batched path")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="required batched-vs-heapq speedup on the M/G/c "
                         "lane (default: 8 full / 3 smoke)")
    ap.add_argument("--json-out", default="BENCH_multiserver.json",
                    help="perf/validation artifact path")
    args = ap.parse_args(argv)
    min_speedup = args.min_speedup
    if min_speedup is None:
        min_speedup = 3.0 if args.smoke else 8.0

    prob = paper_problem()
    t_tab = _service_table(prob, LSTAR)
    pi = np.asarray(prob.tasks.pi)
    es = float(np.sum(pi * t_tab))
    cs, rhos, n_seeds, n_queries, warm_frac = _grid(smoke=args.smoke)
    warm = int(warm_frac * n_queries)
    cells = [(c, rho) for c in cs for rho in rhos]
    emit("multiserver.grid", f"{len(cells)}x{n_seeds}x{n_queries}",
         f"c={cs}, rho={rhos}, {len(cells) * n_seeds * n_queries} queries")

    # one batch per cell (its own lam), generated once and reused by both
    # pipelines so the speedup compares identical work
    batches = {}
    for c, rho in cells:
        lam = rho * c / es
        batches[(c, rho)] = generate_streams(prob.tasks, lam, n_seeds,
                                             n_queries, seed=0)

    # --- batched M/G/c pipeline (steady state, best of 4) -----------------
    # the whole (cell x seed) panel rides ONE kernel call: the free-time
    # panel supports per-stream server counts, so cells with different c
    # coexist in the batch and the per-query Python step amortizes over
    # every stream of the grid at once
    arr_all = np.stack([batches[cell].arrivals for cell in cells])
    svc_all = t_tab[np.stack([batches[cell].types for cell in cells])]
    c_all = np.array([c for c, _ in cells])[:, None]       # [cells, 1]

    def run_batched():
        start, finish = free_server_numpy(arr_all, svc_all, c_all)
        return {cell: (start[i], finish[i])
                for i, cell in enumerate(cells)}

    traj = run_batched()          # warm caches
    t_batched = np.inf
    for _ in range(4):
        t0 = time.perf_counter()
        traj = run_batched()
        t_batched = min(t_batched, time.perf_counter() - t0)

    # --- legacy pipeline: one heapq c-server loop per stream --------------
    t_legacy = np.inf
    for _ in range(2):
        t0 = time.perf_counter()
        legacy_wait = {}
        for (c, rho), batch in batches.items():
            svc = t_tab[batch.types]
            waits = np.empty(n_seeds)
            for s in range(n_seeds):
                st, _ = event_loop_mgc(batch.arrivals[s], svc[s],
                                       batch.arrivals[s], c)
                waits[s] = (st - batch.arrivals[s])[warm:].mean()
            legacy_wait[(c, rho)] = waits
        t_legacy = min(t_legacy, time.perf_counter() - t0)
    speedup = t_legacy / max(t_batched, 1e-12)

    # --- correctness: exact anchor + Erlang-C/Lee-Longton validation ------
    anchor_c, anchor_rho = cells[-1]
    batch = batches[(anchor_c, anchor_rho)]
    svc = t_tab[batch.types]
    st_b, fi_b = traj[(anchor_c, anchor_rho)]
    worst = 0.0
    for s in range(min(n_seeds, 4)):
        st_r, fi_r = event_loop_mgc(batch.arrivals[s], svc[s],
                                    batch.arrivals[s], anchor_c)
        worst = max(worst, float(np.max(np.abs(st_b[s] - st_r))),
                    float(np.max(np.abs(fi_b[s] - fi_r))))
    assert worst <= 1e-9, f"batched/heapq anchor deviation {worst:.2e}"
    emit("multiserver.anchor", f"{worst:.1e}",
         "max per-query |batched - heapq| on the anchor cell")

    validation = []
    for (c, rho), batch in batches.items():
        st, _ = traj[(c, rho)]
        waits = (st - batch.arrivals)[:, warm:].mean(axis=1)
        lam = rho * c / es
        pred = float(mgc_wait_np(prob.tasks, LSTAR, lam, c))
        ci = float(ci95(waits))
        gap = float(waits.mean() - pred)
        ok = abs(gap) <= ci + LL_RTOL[rho] * pred
        assert ok, (f"c={c} rho={rho}: DES {waits.mean():.4f}+-{ci:.4f} vs "
                    f"Lee-Longton {pred:.4f}")
        # the legacy pipeline saw the same streams: means must agree
        assert abs(waits.mean() - legacy_wait[(c, rho)].mean()) <= 1e-9
        validation.append({
            "c": c, "rho": rho, "lam": lam,
            "des_mean_wait": float(waits.mean()), "ci95": ci,
            "lee_longton_wait": pred, "gap": gap,
            "rel_error": gap / pred, "allowance_rel": LL_RTOL[rho],
        })
        emit(f"multiserver.validate.c{c}_rho{rho}",
             f"{gap / pred:+.3f}",
             f"DES-vs-Lee-Longton relative gap (ci {ci / pred:.3f})")

    # --- SRPT lane --------------------------------------------------------
    # sweep-shaped batch: the busy-period kernel amortizes over streams,
    # so its lane runs the seed count a discipline sweep would use
    lam1 = 0.8 / es
    srpt_seeds = 96
    sbatch = generate_streams(prob.tasks, lam1, srpt_seeds,
                              min(n_queries, 2000), seed=1)
    ssvc = t_tab[sbatch.types]
    fin_s, ovf = srpt_numpy(sbatch.arrivals, ssvc)      # warm
    t_srpt = np.inf
    for _ in range(3):
        t0 = time.perf_counter()
        fin_s, ovf = srpt_numpy(sbatch.arrivals, ssvc)
        t_srpt = min(t_srpt, time.perf_counter() - t0)
    assert not ovf.any()
    t_srpt_ref = np.inf
    for _ in range(2):
        t0 = time.perf_counter()
        ref_fins = [srpt_event_loop(sbatch.arrivals[s], ssvc[s])
                    for s in range(sbatch.n_seeds)]
        t_srpt_ref = min(t_srpt_ref, time.perf_counter() - t0)
    worst_srpt = max(float(np.max(np.abs(fin_s[s] - ref_fins[s])))
                     for s in range(sbatch.n_seeds))
    assert worst_srpt <= 1e-9, f"srpt anchor deviation {worst_srpt:.2e}"
    srpt_speedup = t_srpt_ref / max(t_srpt, 1e-12)
    # pathwise optimality vs FIFO on the same streams
    _, fifo_fin = lindley_numpy(sbatch.arrivals, ssvc)
    srpt_sys = (fin_s - sbatch.arrivals).mean()
    fifo_sys = (fifo_fin - sbatch.arrivals).mean()
    assert srpt_sys <= fifo_sys + 1e-9
    emit("multiserver.srpt_anchor", f"{worst_srpt:.1e}",
         f"pinned vs heapq; sys cut vs FIFO {fifo_sys - srpt_sys:.3f}s")
    emit("multiserver.srpt_speedup", f"{srpt_speedup:.1f}x",
         f"busy-period kernel vs heapq ({t_srpt:.3f}s vs {t_srpt_ref:.3f}s)")

    grid_queries = len(cells) * n_seeds * n_queries
    qps = grid_queries / max(t_batched, 1e-12)
    emit("multiserver.legacy_s", f"{t_legacy:.2f}", "heapq loops, full grid")
    emit("multiserver.batched_s", f"{t_batched:.3f}",
         f"next-free-server kernel, speedup {speedup:.1f}x")
    emit("multiserver.qps", f"{qps:,.0f}", "simulated queries / wall-second")
    emit("multiserver.speedup_ok", bool(speedup >= min_speedup),
         f"acceptance: >= {min_speedup:.0f}x over the heapq loop")

    payload = {
        "mode": "smoke" if args.smoke else "full",
        "grid": {"cs": list(cs), "rhos": list(rhos), "n_seeds": n_seeds,
                 "n_queries": n_queries, "warmup": warm,
                 "policy": list(map(float, LSTAR))},
        "timings": {"legacy_s": t_legacy, "batched_s": t_batched,
                    "speedup": speedup, "queries_per_s": qps,
                    "min_speedup": min_speedup,
                    "srpt_kernel_s": t_srpt, "srpt_heapq_s": t_srpt_ref,
                    "srpt_speedup": srpt_speedup},
        "validation": validation,
        "srpt": {"lam": lam1, "mean_system_time": float(srpt_sys),
                 "fifo_mean_system_time": float(fifo_sys),
                 "anchor_max_abs": worst_srpt},
        "anchor_max_abs": worst,
    }
    with open(args.json_out, "w") as fh:
        json.dump(payload, fh, indent=1)
    emit("multiserver.json", args.json_out, "artifact written")

    if args.smoke:
        assert t_batched <= args.budget_s, (
            f"smoke budget blown: {t_batched:.2f}s > {args.budget_s}s")
    assert speedup >= min_speedup, (
        f"batched M/G/c path only {speedup:.1f}x faster than the heapq "
        f"loop (need {min_speedup:.0f}x)")


if __name__ == "__main__":
    main()
