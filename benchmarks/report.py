"""Benchmark reporting: EXPERIMENTS.md tables + the CI baseline gate.

Two roles:

* ``python -m benchmarks.report [dryrun|roofline|all]`` — generate the
  EXPERIMENTS.md dry-run / roofline tables from ``results/`` (historical
  behavior, unchanged).
* ``python -m benchmarks.report --check --smoke-dir DIR`` — the CI gate:
  compare every smoke-run ``BENCH_*.json`` in ``DIR`` against the
  committed full-run artifact of the same family (repo root by default)
  and fail the build when a smoke metric drops below its
  relative-tolerance floor.

The floors are deliberately coarse: committed artifacts are produced on
a quiet dev machine with the full grids, smoke runs on small shared CI
runners with reduced grids, so only order-of-magnitude regressions (a
fast path silently falling back to the scalar pipeline, a kernel losing
its batching, a corrupted artifact) are actionable here — the tighter
wall-clock budgets and in-bench assertions live in each benchmark
itself. Structural checks are strict: the committed baseline must be a
``full`` run, the smoke artifact a ``smoke`` run, and with ``--require``
every listed family must have produced an artifact.
"""
from __future__ import annotations

import argparse
import glob
import json
import pathlib
import sys

RES = pathlib.Path("results")


# Per-family gates: (metric name, extractor, bound[, kind]). Kind
# "floor_rel" (default, 3-tuples) requires smoke >= bound * committed;
# kind "ceil_abs" requires smoke <= bound absolutely — for metrics where
# LOWER is better and the budget is machine-independent (instrumentation
# overhead fractions, histogram percentile error). Extractors raise
# KeyError on malformed artifacts, which the gate reports as a failure.
def _min_arch_speedup(d: dict) -> float:
    return min(a["speedup"] for a in d["archs"].values())


GATES = {
    "BENCH_disciplines.json": [
        ("timings.speedup", lambda d: d["timings"]["speedup"], 0.15),
        ("timings.queries_per_s",
         lambda d: d["timings"]["queries_per_s"], 0.02),
    ],
    "BENCH_solver_grid.json": [
        ("speedup_vs_scalar", lambda d: d["speedup_vs_scalar"], 0.02),
        ("grid_cells_per_s", lambda d: d["grid_cells_per_s"], 0.02),
    ],
    "BENCH_engine.json": [
        ("min_arch_speedup", _min_arch_speedup, 0.25),
    ],
    "BENCH_multiserver.json": [
        ("timings.speedup", lambda d: d["timings"]["speedup"], 0.15),
        ("timings.queries_per_s",
         lambda d: d["timings"]["queries_per_s"], 0.02),
    ],
    "BENCH_replay.json": [
        ("virtual.queries_per_s",
         lambda d: d["virtual"]["queries_per_s"], 0.02),
        # accuracy of the online lambda estimator at the end of the run;
        # scale-free in [0, 1], so the floor is a fraction of the committed
        # full-run accuracy, not of a throughput
        ("estimation.lam_accuracy",
         lambda d: d["estimation"]["lam_accuracy"], 0.5),
    ],
    "BENCH_paged.json": [
        # admission density at equal KV memory: machine-independent
        # ratio, so the smoke floor is a fraction of the committed run
        # (the >1.0 strict assert lives in the bench itself)
        ("occupancy.paged_vs_slot",
         lambda d: d["occupancy"]["paged_vs_slot_mean_ratio"], 0.6),
        # corrected analytics vs occupancy-dependent DES: absolute
        # ceiling = the documented envelope (bench asserts its own
        # mode-specific bound too)
        ("analytics.rel_err",
         lambda d: d["analytics"]["rel_err"], 0.35, "ceil_abs"),
    ],
    "BENCH_resilience.json": [
        # ladder-vs-naive deadline goodput under 2x overload: a
        # machine-independent ratio; the strict > 1 assert lives in the
        # bench itself, the gate catches an order-of-magnitude collapse
        ("burst.goodput_ratio",
         lambda d: d["burst"]["goodput_ratio"], 0.5),
        # ladder p99 wait must never exceed the naive baseline's
        ("burst.p99_wait_ratio",
         lambda d: d["burst"]["p99_wait_ratio"], 1.0, "ceil_abs"),
        # retry-storm metastability: impatient goodput over patient
        # goodput — collapse, not graceful degradation
        ("retry.collapse_ratio",
         lambda d: d["retry"]["collapse_ratio"], 0.3, "ceil_abs"),
        # analytic effective-arrival-rate fixed point vs the DES at a
        # stable operating point
        ("retry.lam_eff_rel_err",
         lambda d: d["retry"]["fixed_point"]["lam_eff_rel_err"],
         0.2, "ceil_abs"),
    ],
    "BENCH_prediction.json": [
        # frontier sweep must stay on the K-lane fast path
        ("timings.queries_per_s",
         lambda d: d["timings"]["queries_per_s"], 0.02),
        # the SPRPT tail crossover must exist (a None/missing value fails
        # as unreadable) and stay inside the documented band — losing the
        # finite crossover means the frontier's structure is gone
        ("crossover.sprpt_p99",
         lambda d: d["crossover"]["sprpt_p99"], 2.5, "ceil_abs"),
    ],
    "BENCH_obs.json": [
        # histogram ingest must stay vectorized (order-of-magnitude floor)
        ("hist.updates_per_s", lambda d: d["hist"]["updates_per_s"], 0.02),
        # enabled-instrumentation overhead: absolute ceilings, generous on
        # noisy runners (the committed full run documents <3% decode and
        # <10% DES on a quiet machine; in-bench asserts enforce those)
        ("overhead.decode_frac",
         lambda d: d["overhead"]["decode_frac"], 0.25, "ceil_abs"),
        ("overhead.des_frac",
         lambda d: d["overhead"]["des_frac"], 0.40, "ceil_abs"),
        # histogram percentile error vs numpy.percentile: the documented
        # 2**-bits bucket bound, machine-independent
        ("hist.max_rel_err",
         lambda d: d["hist"]["max_rel_err"], 0.032, "ceil_abs"),
    ],
}


def check_benchmarks(smoke_dir: str, baseline_dir: str = ".",
                     require: bool = False) -> int:
    """Gate smoke artifacts against committed baselines; returns #failures."""
    smoke_dir = pathlib.Path(smoke_dir)
    baseline_dir = pathlib.Path(baseline_dir)
    failures = 0
    rows = []
    for family, gates in GATES.items():
        base_path = baseline_dir / family
        smoke_path = smoke_dir / family
        if not base_path.exists():
            rows.append((family, "-", "no committed baseline", "skip"))
            continue
        if not smoke_path.exists():
            status = "FAIL" if require else "skip"
            failures += require
            rows.append((family, "-", "smoke artifact missing", status))
            continue
        base = json.load(open(base_path))
        smoke = json.load(open(smoke_path))
        if base.get("mode") != "full":
            rows.append((family, "mode",
                         f"committed baseline is {base.get('mode')!r}, "
                         "expected 'full'", "FAIL"))
            failures += 1
        if smoke.get("mode") != "smoke":
            rows.append((family, "mode",
                         f"smoke artifact is {smoke.get('mode')!r}, "
                         "expected 'smoke'", "FAIL"))
            failures += 1
        for gate in gates:
            name, extract, bound = gate[:3]
            kind = gate[3] if len(gate) > 3 else "floor_rel"
            try:
                b = float(extract(base))
                s = float(extract(smoke))
            except (KeyError, TypeError, ValueError) as e:
                rows.append((family, name, f"unreadable metric: {e!r}",
                             "FAIL"))
                failures += 1
                continue
            if kind == "ceil_abs":
                ok = s <= bound
                rows.append((family, name,
                             f"smoke {s:.3g} vs ceiling {bound:.3g} "
                             f"(committed {b:.3g})",
                             "ok" if ok else "FAIL"))
            else:
                floor = bound * b
                ok = s >= floor
                rows.append((family, name,
                             f"smoke {s:.3g} vs floor {floor:.3g} "
                             f"({bound:.0%} of committed {b:.3g})",
                             "ok" if ok else "FAIL"))
            failures += not ok
    width = max(len(r[0]) for r in rows) if rows else 0
    print("## Benchmark baseline gate\n")
    for family, metric, detail, status in rows:
        print(f"{status:>4}  {family:<{width}}  {metric:<22}  {detail}")
    print(f"\n{failures} failing check(s)" if failures else "\nall green")
    return failures


def dryrun_table() -> str:
    rows = []
    for f in sorted(glob.glob(str(RES / "dryrun" / "*__dryrun.json"))):
        r = json.load(open(f))
        if not r.get("ok"):
            rows.append((r["arch"], r["shape"], r["mesh"], "FAIL", "", ""))
            continue
        mem = r["memory_analysis"]
        rows.append((
            r["arch"], r["shape"], r["mesh"],
            "ok",
            f"{mem.get('temp_size_in_bytes', 0) / 2**30:.1f}",
            f"{r['compile_s']:.0f}",
        ))
    out = ["| arch | shape | mesh | lower+compile | temp GiB/dev | compile s |",
           "|---|---|---|---|---:|---:|"]
    for row in rows:
        out.append("| " + " | ".join(str(x) for x in row) + " |")
    return "\n".join(out)


def roofline_table(tag: str = "") -> str:
    pat = f"*__roofline{('__' + tag) if tag else ''}.json"
    out = ["| arch | shape | compute s | memory s | collective s | "
           "bottleneck | 6ND/HLO ratio |",
           "|---|---|---:|---:|---:|---|---:|"]
    for f in sorted(glob.glob(str(RES / "roofline" / pat))):
        r = json.load(open(f))
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | |")
            continue
        x = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {x['compute_s']:.3e} | "
            f"{x['memory_s']:.3e} | {x['collective_s']:.3e} | "
            f"**{x['bottleneck']}** | {x['model_flops_ratio']:.3f} |")
    return "\n".join(out)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("which", nargs="?", default="all",
                    choices=["all", "dryrun", "roofline"],
                    help="EXPERIMENTS.md table(s) to print")
    ap.add_argument("--check", action="store_true",
                    help="gate smoke BENCH_*.json against committed "
                         "baselines instead of printing tables")
    ap.add_argument("--smoke-dir", default="bench-artifacts",
                    help="directory holding the smoke-run artifacts")
    ap.add_argument("--baseline-dir", default=".",
                    help="directory holding the committed full-run "
                         "artifacts (repo root)")
    ap.add_argument("--require", action="store_true",
                    help="fail if any gated family has no smoke artifact")
    args = ap.parse_args(argv)
    if args.check:
        sys.exit(1 if check_benchmarks(args.smoke_dir, args.baseline_dir,
                                       require=args.require) else 0)
    if args.which in ("all", "dryrun"):
        print("## Dry-run table\n")
        print(dryrun_table())
    if args.which in ("all", "roofline"):
        print("\n## Roofline table\n")
        print(roofline_table())


if __name__ == "__main__":
    main()
