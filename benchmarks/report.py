"""Generate the EXPERIMENTS.md dry-run + roofline tables from results/."""
from __future__ import annotations

import glob
import json
import pathlib
import sys

RES = pathlib.Path("results")


def dryrun_table() -> str:
    rows = []
    for f in sorted(glob.glob(str(RES / "dryrun" / "*__dryrun.json"))):
        r = json.load(open(f))
        if not r.get("ok"):
            rows.append((r["arch"], r["shape"], r["mesh"], "FAIL", "", ""))
            continue
        mem = r["memory_analysis"]
        rows.append((
            r["arch"], r["shape"], r["mesh"],
            "ok",
            f"{mem.get('temp_size_in_bytes', 0) / 2**30:.1f}",
            f"{r['compile_s']:.0f}",
        ))
    out = ["| arch | shape | mesh | lower+compile | temp GiB/dev | compile s |",
           "|---|---|---|---|---:|---:|"]
    for row in rows:
        out.append("| " + " | ".join(str(x) for x in row) + " |")
    return "\n".join(out)


def roofline_table(tag: str = "") -> str:
    pat = f"*__roofline{('__' + tag) if tag else ''}.json"
    out = ["| arch | shape | compute s | memory s | collective s | "
           "bottleneck | 6ND/HLO ratio |",
           "|---|---|---:|---:|---:|---|---:|"]
    for f in sorted(glob.glob(str(RES / "roofline" / pat))):
        r = json.load(open(f))
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | |")
            continue
        x = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {x['compute_s']:.3e} | "
            f"{x['memory_s']:.3e} | {x['collective_s']:.3e} | "
            f"**{x['bottleneck']}** | {x['model_flops_ratio']:.3f} |")
    return "\n".join(out)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("## Dry-run table\n")
        print(dryrun_table())
    if which in ("all", "roofline"):
        print("\n## Roofline table\n")
        print(roofline_table())
