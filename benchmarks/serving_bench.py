"""End-to-end serving benchmark: the real server (allocator + scheduler +
virtual clock) under the paper workload, plus beyond-paper modes
(SJF/priority disciplines, batched service, online adaptation, M/G/c).

The FIFO row is cross-checked against two independent predictions: the
Pollaczek-Khinchine formula and a seed-averaged batched Lindley DES
(``queueing_sim.sweep``) at the allocator's integer budgets."""
from __future__ import annotations

import numpy as np

from repro.core import paper_problem, solve_mgc
from repro.queueing_sim import generate_stream, pk_prediction, sweep
from repro.serving import LLMServer, ServerConfig

from .common import emit, timed


def main() -> None:
    prob = paper_problem()
    stream = generate_stream(prob.tasks, prob.server.lam, 5000, seed=3)

    def run(**kw):
        srv = LLMServer(prob, ServerConfig(online_adaptation=False, **kw))
        return srv.run(stream), srv

    (fifo, srv), us = timed(lambda: run(), repeat=1)
    budgets = np.asarray(srv.allocator.solution.lengths_int, dtype=float)
    pred = pk_prediction(prob, list(budgets))
    des = sweep(prob, {"opt": budgets}, lams=[prob.server.lam], n_seeds=8,
                n_queries=5000, seed=3, clip_unstable=False)
    emit("serve.fifo.mean_system_time", f"{fifo.mean_system_time:.4f}",
         f"pk={pred['mean_system_time']:.4f}, "
         f"des={des.mean_system_time[0, 0]:.4f}"
         f"+-{des.ci_system_time[0, 0]:.4f}")
    emit("serve.fifo.p99_system_time", f"{fifo.p99_system_time:.4f}", "")
    emit("serve.fifo.objective", f"{fifo.objective:.4f}", "")
    emit("serve.fifo.utilization", f"{fifo.utilization:.4f}", "")
    emit("serve.fifo.throughput_qps", f"{5000 / (us / 1e6):.0f}",
         "simulated queries per wall-second")

    sjf, _ = run(discipline="sjf")
    emit("serve.sjf.mean_wait", f"{sjf.mean_wait:.4f}",
         f"fifo={fifo.mean_wait:.4f}")
    pri, _ = run(discipline="priority")
    emit("serve.priority.objective", f"{pri.objective:.4f}", "")
    for bs in (2, 4, 8):
        rep, _ = run(batch_size=bs)
        emit(f"serve.batched_{bs}.mean_system_time",
             f"{rep.mean_system_time:.4f}", f"objective={rep.objective:.4f}")
    online_srv = LLMServer(prob, ServerConfig(online_adaptation=True))
    online = online_srv.run(stream)
    emit("serve.online.objective", f"{online.objective:.4f}",
         f"resolves={online.n_resolves}")

    # M/G/c replica planning (beyond paper)
    for c in (1, 2, 4):
        r = solve_mgc(prob, c)
        emit(f"serve.mgc.replicas_{c}.J", f"{float(r.value):.4f}",
             f"iters={r.iterations}")

    # wall mode on the REAL engine: service clock = wall time of the
    # continuous-batching fast path (batched admission + fused chunked
    # decode), reduced model so CPU decode stays tractable
    import jax

    from repro.configs import get_config
    from repro.core import Problem, ServerParams
    from repro.models import init_params, reduced
    from repro.serving.continuous import ContinuousBatchingEngine

    cfg = reduced(get_config("qwen3-0.6b"), d_model=128)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(cfg, params, max_slots=4, capacity=128,
                                   chunk=16)
    small = Problem(tasks=prob.tasks, server=ServerParams(0.1, 2.0, 64.0))
    wall_stream = generate_stream(small.tasks, 0.1, 16, seed=5,
                                  prompt_len_range=(4, 8))
    def run_wall():
        srv = LLMServer(small, ServerConfig(mode="wall", batch_size=4,
                                            generate_tokens=True,
                                            max_extra_tokens=2,
                                            online_adaptation=False),
                        engine=eng)
        return srv.run(wall_stream)

    wall_rep, wall_us = timed(run_wall, repeat=1, warmup=1)
    emit("serve.wall.tokens_generated", f"{wall_rep.tokens_generated}",
         f"n={wall_rep.n}, continuous fast path (batched admission + "
         f"chunked decode)")
    emit("serve.wall.tokens_per_s",
         f"{wall_rep.tokens_generated / (wall_us / 1e6):.0f}",
         "real-engine wall-clock decode throughput, compile excluded")


if __name__ == "__main__":
    main()
