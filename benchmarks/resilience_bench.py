"""Overload-resilience benchmark: the degradation ladder vs a naive
(no-admission) loop under an arrival burst, plus the retry-storm
metastability curve pinned to the analytic effective-arrival-rate fixed
point.

Two lanes:

* **burst** — the paper problem re-rated to rho = 0.6 at its own oracle
  budgets, hit with a compressed-arrival burst that lifts the offered
  load to 2x capacity, with stragglers, poisoned observations and
  dropped completions riding along (``repro.faults``). The same trace
  and fault bank run twice: once through the guarded stack
  (``AdmissionController`` degradation ladder + drift-gated re-solve),
  once through a naive FIFO that serves every request at the static
  oracle budgets. The guarded stack must win on BOTH deadline-goodput
  and p99 wait, and recover to the steady-state wait level no later
  than the naive loop.
* **retry** — M/G/1 with deadlines and orphaned-service retries
  (``queueing_sim.impatience``): sweeps client patience at rho = 0.95
  and scores the goodput collapse (metastability), pins the batched
  NumPy lane bitwise against the heapq reference, and checks the
  ``core.queueing.retry_fixed_point`` effective arrival rate against
  the DES at a stable operating point (and its lam * (K + 1) pin at an
  unstable one).

    PYTHONPATH=src python -m benchmarks.resilience_bench [--smoke]

Writes ``BENCH_resilience.json`` (``--json-out`` to relocate). The
committed artifact is a full run; CI runs ``--smoke`` and gates the
machine-independent ratios through ``benchmarks/report.py --check``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np

from repro.core import paper_problem, retry_fixed_point
from repro.core.allocator import solve
from repro.faults import (ArrivalBurst, DroppedCompletions, FaultSet,
                          ObservationCorruption, StragglerDecode)
from repro.obs.monitor import DriftMonitor
from repro.queueing_sim import (RetryPolicy, Segment, generate_drift_trace,
                                impatience_event_loop, impatience_numpy,
                                summarize_impatience)
from repro.serving import (AdmissionConfig, AdmissionController,
                           ReplayConfig, ReplayHarness)

from .common import emit

#: a completion is "timely" when it finishes within this many seconds of
#: arrival — roughly 10x the steady-state system time of the burst-lane
#: operating point, so steady traffic always makes it and burst-bloated
#: waits do not
DEADLINE_S = 10.0


def _recovery_s(blocks, burst_t0: float, burst_t1: float,
                horizon: float) -> float:
    """Seconds after the burst until block mean waits return to twice the
    pre-burst steady level; the full remaining horizon when they never do."""
    pre = [b.mean_wait for b in blocks[2:] if b.t_end < burst_t0]
    steady = float(np.mean(pre)) if pre else 0.0
    bar = max(2.0 * steady, 0.5)
    for b in blocks:
        if b.t_start >= burst_t1 and b.mean_wait <= bar:
            return float(b.t_start - burst_t1)
    return float(horizon - burst_t1)


def burst_lane(prob, n_queries: int, overload_rho: float = 2.0) -> dict:
    """Ladder vs naive on the same burst trace and fault bank."""
    oracle = np.asarray(solve(prob).lengths_int, dtype=np.int64)
    t0v = np.asarray(prob.tasks.t0)
    cv = np.asarray(prob.tasks.c)
    pi = np.asarray(prob.tasks.pi)
    es = float(np.sum(pi * (t0v + cv * oracle)))
    rho0 = 0.6
    lam0 = rho0 / es
    hot = dataclasses.replace(
        prob, server=dataclasses.replace(prob.server, lam=lam0))
    oracle_hot = np.asarray(solve(hot).lengths_int, dtype=np.int64)
    es_hot = float(np.sum(pi * (t0v + cv * oracle_hot)))
    factor = overload_rho / (lam0 * es_hot)

    trace = generate_drift_trace(hot.tasks, [Segment(n_queries, lam0)],
                                 seed=13)
    # burst window in ORIGINAL arrival time: queries [30%, 65%] of the
    # trace; after gap compression it spans [t_b0, t_b0 + dt / factor]
    t_b0 = float(trace.arrivals[int(0.30 * n_queries)])
    t_b1 = float(trace.arrivals[int(0.65 * n_queries)])
    burst_end = t_b0 + (t_b1 - t_b0) / factor

    def fault_bank():
        return FaultSet(ArrivalBurst(t_b0, t_b1, factor),
                        StragglerDecode(0.02, 2.0, seed=1),
                        ObservationCorruption(0.02, "nan", seed=2),
                        DroppedCompletions(0.02, seed=3))

    cfg = ReplayConfig(block_size=256, resolve_mode="drift",
                       est_halflife=128.0)
    arms = {}
    for name, adm, fixed in (
            ("ladder", AdmissionController(
                oracle_hot, hot.server.l_max,
                AdmissionConfig(rho_high=0.85, rho_low=0.6,
                                dwell_down=800.0)), None),
            # naive FIFO: every request served at the static oracle
            # budgets — no ladder, no re-solve, no shedding
            ("naive", None, oracle_hot)):
        t_wall = time.perf_counter()
        res = ReplayHarness(hot, cfg, monitor=DriftMonitor(),
                            admission=adm,
                            faults=fault_bank()).run_virtual(
                                trace, fixed_lengths=fixed)
        elapsed = time.perf_counter() - t_wall
        sm = res.served_mask()
        gp = res.goodput(DEADLINE_S)
        rec = _recovery_s(res.blocks, t_b0, burst_end,
                          float(res.arrivals[-1]))
        arms[name] = {
            "elapsed_s": elapsed,
            "queries_per_s": n_queries / elapsed,
            "goodput": gp["goodput"],
            "n_good": gp["n_good"],
            "shed_fraction": gp["shed_fraction"],
            "p99_wait": float(np.percentile(res.waits[sm], 99)),
            "mean_wait": float(res.waits[sm].mean()),
            "recovery_s": rec,
            "n_resolves": res.n_resolves,
            "max_level": (max(b.level for b in res.blocks)
                          if adm is not None else 0),
            "final_level": (res.admission["level"]
                            if adm is not None else 0),
            "degradation_occupancy":
                ({str(k): v for k, v in
                  res.admission["occupancy"].items()}
                 if adm is not None else None),
            "budget_linf_gap":
                int(np.max(np.abs(res.final_budgets - oracle_hot))),
        }
        emit(f"resilience.burst.{name}.goodput",
             f"{gp['goodput']:.4f}",
             f"p99_wait={arms[name]['p99_wait']:.2f}s, "
             f"recovery={rec:.0f}s")

    lad, nai = arms["ladder"], arms["naive"]
    out = {
        "n_queries": n_queries, "lam0": lam0, "rho0": rho0,
        "burst_factor": factor, "overload_rho": overload_rho,
        "deadline_s": DEADLINE_S,
        "burst_window_s": [t_b0, burst_end],
        "ladder": lad, "naive": nai,
        "goodput_ratio": lad["goodput"] / max(nai["goodput"], 1e-12),
        "p99_wait_ratio": lad["p99_wait"] / max(nai["p99_wait"], 1e-12),
        "recovery_ratio": lad["recovery_s"] / max(nai["recovery_s"], 1e-9),
    }
    emit("resilience.burst.goodput_ratio", f"{out['goodput_ratio']:.3f}",
         "ladder vs naive under overload; must be > 1")
    emit("resilience.burst.p99_wait_ratio", f"{out['p99_wait_ratio']:.3f}",
         "ladder vs naive; must be < 1")
    # the headline claim, asserted in both modes: under overload the
    # ladder sustains strictly higher goodput AND lower p99 wait
    assert out["goodput_ratio"] > 1.0, \
        f"ladder goodput did not beat naive: {out['goodput_ratio']:.3f}"
    assert out["p99_wait_ratio"] < 1.0, \
        f"ladder p99 wait did not beat naive: {out['p99_wait_ratio']:.3f}"
    assert lad["recovery_s"] <= nai["recovery_s"], \
        "ladder recovered later than naive"
    assert lad["max_level"] >= 1 and lad["final_level"] == 0, \
        "ladder never engaged or never de-escalated"
    return out


def retry_lane(n: int, rho: float = 0.95,
               taus=(200.0, 50.0, 20.0, 10.0, 5.0, 2.0)) -> dict:
    """Metastability curve + lane pin + analytic fixed-point check."""
    rng = np.random.default_rng(11)
    a = np.cumsum(rng.exponential(1.0 / rho, size=n))
    s = rng.exponential(1.0, size=n)
    lam = 1.0 / float(np.diff(a).mean())
    es, es2 = float(s.mean()), float((s ** 2).mean())

    # lane pin: the batched NumPy lane must match the heapq reference
    # bitwise on a retrying policy before any of its numbers are trusted
    pin_pol = RetryPolicy(patience=taus[-1], max_retries=3, backoff0=0.5)
    n_pin = min(n, 1500)
    ref = impatience_event_loop(a[:n_pin], s[:n_pin], pin_pol)
    got = impatience_numpy(a[:n_pin], s[:n_pin], pin_pol)
    pin_ok = (np.array_equal(got.served, ref.served)
              and np.array_equal(got.wait, ref.wait, equal_nan=True))
    assert pin_ok, "impatience NumPy lane diverged from heapq reference"

    curve = []
    t_wall = time.perf_counter()
    for tau in taus:
        pol = RetryPolicy(patience=float(tau), max_retries=3, backoff0=0.5)
        res = impatience_numpy(a, s, pol)
        summ = summarize_impatience(res, a, s, pol)
        fp = retry_fixed_point(lam, es, es2, patience=float(tau),
                               max_retries=3)
        curve.append({
            "patience": float(tau),
            "goodput": summ["goodput"],
            "timeout_frac": summ["timeout_frac"],
            "lam_eff_measured": summ["lam_eff"],
            "lam_eff_analytic": fp.lam_eff,
            "stable_analytic": bool(fp.stable),
        })
        emit(f"resilience.retry.tau{tau:g}.goodput",
             f"{summ['goodput']:.4f}",
             f"lam_eff={summ['lam_eff']:.3f} "
             f"(analytic {fp.lam_eff:.3f}, "
             f"{'stable' if fp.stable else 'UNSTABLE'})")
    elapsed = time.perf_counter() - t_wall
    good = [r["goodput"] for r in curve]
    collapse = good[-1] / max(good[0], 1e-12)
    # the metastability curve: goodput monotone non-increasing as
    # patience tightens, ending in collapse — not graceful degradation
    assert all(g0 >= g1 - 1e-9 for g0, g1 in zip(good, good[1:])), \
        f"goodput not monotone along the patience sweep: {good}"
    assert collapse < 0.3, \
        f"no retry-storm collapse: goodput ratio {collapse:.3f}"
    # impatient retries pin the attempt rate at lam * (K + 1)
    assert curve[-1]["lam_eff_measured"] > 0.85 * lam * 4

    # fixed point vs DES at a STABLE operating point (rho = 0.7,
    # patient): the analytic effective rate must match the measured one
    a2 = np.cumsum(rng.exponential(1.0 / 0.7, size=n))
    s2 = rng.exponential(1.0, size=n)
    lam2 = 1.0 / float(np.diff(a2).mean())
    pol2 = RetryPolicy(patience=30.0, max_retries=3, backoff0=0.5)
    meas2 = summarize_impatience(impatience_numpy(a2, s2, pol2),
                                 a2, s2, pol2)["lam_eff"]
    fp2 = retry_fixed_point(lam2, float(s2.mean()), float((s2 ** 2).mean()),
                            patience=30.0, max_retries=3)
    rel_err = abs(fp2.lam_eff - meas2) / meas2
    emit("resilience.retry.lam_eff_rel_err", f"{rel_err:.4f}",
         f"analytic={fp2.lam_eff:.4f} vs DES={meas2:.4f} at rho=0.7")
    assert fp2.stable and fp2.converged
    assert rel_err < 0.1, \
        f"fixed point off the DES by {rel_err:.3f} at a stable point"
    return {
        "n": n, "rho": rho, "lam": lam, "elapsed_s": elapsed,
        "attempts_per_s": n * len(taus) / elapsed,
        "curve": curve,
        "collapse_ratio": collapse,
        "lane_pin_ok": bool(pin_ok),
        "fixed_point": {
            "rho": 0.7, "patience": 30.0,
            "lam_eff_analytic": fp2.lam_eff,
            "lam_eff_measured": meas2,
            "lam_eff_rel_err": rel_err,
            "stable": bool(fp2.stable),
        },
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small lanes + relaxed floors (CI)")
    ap.add_argument("--json-out", default="BENCH_resilience.json")
    args = ap.parse_args(argv)

    n_burst, n_retry = (12_000, 4_000) if args.smoke else (40_000, 20_000)

    prob = paper_problem()
    out = {
        "mode": "smoke" if args.smoke else "full",
        "burst": burst_lane(prob, n_burst),
        "retry": retry_lane(n_retry),
    }
    with open(args.json_out, "w") as f:
        json.dump(out, f, indent=1)
    emit("resilience.artifact", args.json_out, out["mode"])


if __name__ == "__main__":
    main()
