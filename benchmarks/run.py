"""Benchmark runner: one module per paper table/figure + system benches.
Prints ``name,value,derived`` CSV rows."""
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

MODULES = (
    "table1",            # Table I: optimal allocations
    "fig2_fit",          # Fig 2: accuracy-curve calibration
    "fig3_policies",     # Fig 3: uniform vs optimal
    "fig4_sensitivity",  # Fig 4: GSM8K budget sweep + eq-41 bound
    "integer_gap",       # Sec III-E sandwich across loads
    "convergence",       # Sec III-C/D solver behaviour + certificates
    "solver_grid_bench",  # vmapped grid solver vs scalar loop (100 cells)
    "serving_bench",     # end-to-end server + ablations + M/G/c
    "engine_bench",      # CPU decode microbench (reduced archs)
    "calibration_bridge",  # roofline -> (t0,c) -> re-solve loop
    "roofline",          # dry-run roofline table (reads results/)
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark modules")
    args = ap.parse_args()
    mods = args.only.split(",") if args.only else MODULES
    failures = 0
    for name in mods:
        print(f"# --- {name} ---", flush=True)
        t0 = time.time()
        try:
            importlib.import_module(f"benchmarks.{name}").main()
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"# {name} FAILED", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
