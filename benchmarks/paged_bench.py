"""Paged-KV serving benchmark: admission density, step-latency fit, and
the occupancy-corrected analytics loop.

The paged tentpole claim measured here: at EQUAL total KV memory, paged
admission (token-granular block reservations over a shared pool) sustains
strictly higher concurrent tokens-in-use than the dense slot path, whose
admission is gated by worst-case per-slot capacity. The workload is many
short requests — each needs ~a third of a dense slot — so the slot engine
strands the rest of every slot's capacity while the paged engine turns it
into admitted concurrency. Greedy token-for-token equality between the
two engines is asserted on the same workload, so the density is never
bought with drift.

Also measured, closing the engine -> analytics loop:

* decode step latency at pinned occupancies b in {1, 2, 4, 8}, fed to
  ``core.batch_service.fit_step_latency`` — the measurement the
  occupancy-corrected queueing model calibrates from,
* the corrected analytics (``batch_service_wait``) vs the
  occupancy-dependent DES (``queueing_sim.simulate_batch_service``) under
  the FITTED step model at moderate load: mean system time must agree
  within the documented envelope,
* KV bytes per pool token for f32 vs int8 pools (machine-independent).

    PYTHONPATH=src python -m benchmarks.paged_bench [--smoke]

Either mode writes ``BENCH_paged.json`` (``--json-out`` to relocate);
``--smoke`` shrinks the workload for CI runners. The committed JSON comes
from a full run on a quiet machine; ``benchmarks/report.py --check``
gates the occupancy ratio (floor_rel) and the analytics error (ceil_abs).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.batch_service import batch_service_wait, fit_step_latency
from repro.core.params import paper_tasks
from repro.models import init_params, reduced
from repro.models.attention import init_paged_cache
from repro.queueing_sim.batch_service import simulate_batch_service
from repro.serving.continuous import ContinuousBatchingEngine

from .common import emit, timed

# equal-memory comparison point: both engines own 512 pool tokens; the
# slot engine can hold 8 concurrent requests (one per dense 64-token
# slot), the paged engine up to 16 rows drawing 24-token reservations
# from the same 512-token pool
GRID = dict(capacity=64, slot_slots=8, paged_slots=16, block_size=8,
            n_blocks=64, chunk=4, prompt_len=8, budget=8, max_extra=2)


def _requests(n: int, grid: dict) -> list:
    rng = np.random.default_rng(0)
    return [(i,
             rng.integers(1, 97, size=grid["prompt_len"]).astype(np.int32),
             grid["budget"], grid["max_extra"]) for i in range(n)]


def _drain_measured(eng, reqs):
    """Serve the whole workload, sampling tokens-in-use each fused step."""
    pending = list(reqs)
    done = {}
    samples = []
    t0 = time.perf_counter()
    while pending or eng.n_active:
        if pending:
            ok = eng.admit_many(pending)
            pending = [r for r, f in zip(pending, ok) if not f]
        for s in eng.step_chunk():
            done[s.rid] = s.tokens
        samples.append(eng.tokens_in_use)
    wall = time.perf_counter() - t0
    toks = sum(len(t) for t in done.values())
    return done, {
        "mean_tokens_in_use": float(np.mean(samples)),
        "peak_tokens_in_use": int(np.max(samples)),
        "pool_tokens": int(eng.pool_tokens),
        "requests": len(done),
        "wall_s": wall,
        "req_per_s": len(done) / wall,
        "tok_per_s": toks / wall,
    }


def bench_occupancy(cfg, params, n_requests: int, grid: dict) -> dict:
    reqs = _requests(n_requests, grid)
    slot = ContinuousBatchingEngine(
        cfg, params, max_slots=grid["slot_slots"], capacity=grid["capacity"],
        chunk=grid["chunk"])
    paged = ContinuousBatchingEngine(
        cfg, params, max_slots=grid["paged_slots"],
        capacity=grid["capacity"], chunk=grid["chunk"], paged=True,
        block_size=grid["block_size"], n_blocks=grid["n_blocks"])
    assert slot.pool_tokens == paged.pool_tokens, "not an equal-memory run"
    done_s, stats_s = _drain_measured(slot, reqs)
    done_p, stats_p = _drain_measured(paged, reqs)
    assert done_p == done_s, "paged tokens drifted from the slot path"
    ratio = stats_p["mean_tokens_in_use"] / stats_s["mean_tokens_in_use"]
    # THE tentpole assertion: equal memory, strictly denser admission
    assert ratio > 1.0, (
        f"paged mean tokens-in-use {stats_p['mean_tokens_in_use']:.1f} not "
        f"above slot path {stats_s['mean_tokens_in_use']:.1f}")
    return {"slot": stats_s, "paged": stats_p,
            "paged_vs_slot_mean_ratio": ratio,
            "tokens_equal": True}


def bench_step_latency(cfg, params, grid: dict, repeat: int) -> dict:
    """Measure one fused decode step at pinned occupancies and fit the
    affine step model the batch-service analytics consume."""
    batch_sizes = [1, 2, 4, 8]
    step_us = []
    for b in batch_sizes:
        eng = ContinuousBatchingEngine(
            cfg, params, max_slots=8, capacity=grid["capacity"],
            chunk=grid["chunk"], paged=True,
            block_size=grid["block_size"])
        # long budgets so nobody retires while we time
        eng.admit_many([(i, np.full(4, 5 + i, np.int32), 40, 0)
                        for i in range(b)])
        _, us = timed(lambda: eng.step_chunk(), repeat=repeat, best=True)
        step_us.append(float(us))
        emit(f"paged.step_us.b{b}", f"{float(us):.0f}",
             f"fused {grid['chunk']}-token chunk at occupancy {b}")
    # per-chunk -> per-step seconds
    secs = [u / grid["chunk"] * 1e-6 for u in step_us]
    model = fit_step_latency(batch_sizes, secs)
    return {"batch_sizes": batch_sizes, "step_chunk_us": step_us,
            "d0": model.d0, "d1": model.d1,
            "ratio_at_8": float(model.ratio(8))}, model


def bench_analytics(model, n_sim: int, max_err: float) -> dict:
    """Corrected analytics vs occupancy-dependent DES under the fitted
    step model, at moderate load (rho/c ~ 0.5-0.7)."""
    tasks = paper_tasks()
    lengths = np.full(tasks.n_tasks, 120.0)
    lam, max_batch = 1.5, 8
    pred = batch_service_wait(tasks, lengths, lam, model, max_batch)
    sim = simulate_batch_service(tasks, lengths, lam, model, max_batch,
                                 n=n_sim, seed=0)
    rel_err = abs(pred.mean_system_time - sim.mean_system_time) \
        / sim.mean_system_time
    assert rel_err <= max_err, (
        f"corrected analytics off DES by {rel_err:.2%} > {max_err:.0%}")
    return {"lam": lam, "max_batch": max_batch,
            "b_bar": pred.b_bar, "ratio": pred.ratio,
            "pred_system_s": pred.mean_system_time,
            "des_system_s": sim.mean_system_time,
            "des_exp_occupancy": sim.exp_occupancy,
            "rel_err": float(rel_err), "max_err": max_err}


def bench_bytes_per_token(cfg, grid: dict) -> dict:
    """KV pool bytes per token, f32 vs int8 (layer-stacked, incl. scales)."""
    import dataclasses as dc

    def bpt(c):
        pc = init_paged_cache(c, batch=2, n_blocks=grid["n_blocks"],
                              block_size=grid["block_size"], n_bt=8)
        total = sum(int(leaf.nbytes) for leaf in
                    (pc.k, pc.v, pc.k_scale, pc.v_scale)
                    if leaf is not None)
        return total / (grid["n_blocks"] * grid["block_size"])

    f32 = bpt(cfg)
    i8 = bpt(dc.replace(cfg, kv_cache_dtype="int8"))
    assert i8 < f32, "int8 pool must be smaller than f32 per token"
    return {"f32_bytes_per_token": f32, "int8_bytes_per_token": i8,
            "compression": f32 / i8}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workload + relaxed envelope (CI)")
    ap.add_argument("--requests", type=int, default=None,
                    help="workload size (default: 64 full / 24 smoke)")
    ap.add_argument("--repeat", type=int, default=5)
    ap.add_argument("--budget-s", type=float, default=None,
                    help="smoke-mode wall-clock budget")
    ap.add_argument("--json-out", default="BENCH_paged.json")
    args = ap.parse_args(argv)
    n_requests = args.requests or (24 if args.smoke else 64)
    n_sim = 1500 if args.smoke else 6000
    max_err = 0.35 if args.smoke else 0.30

    cfg = reduced(get_config("qwen3-0.6b"))
    params = init_params(cfg, jax.random.PRNGKey(0))

    t_start = time.perf_counter()
    occ = bench_occupancy(cfg, params, n_requests, GRID)
    emit("paged.mean_tokens_in_use",
         f"{occ['paged']['mean_tokens_in_use']:.1f}",
         f"slot={occ['slot']['mean_tokens_in_use']:.1f}, "
         f"ratio={occ['paged_vs_slot_mean_ratio']:.2f}x at equal "
         f"{occ['slot']['pool_tokens']}-token memory")
    emit("paged.tok_per_s", f"{occ['paged']['tok_per_s']:.0f}",
         f"slot={occ['slot']['tok_per_s']:.0f} (CPU debug figures)")

    step, model = bench_step_latency(cfg, params, GRID, repeat=args.repeat)
    emit("paged.step_fit", f"d0={step['d0']:.2e},d1={step['d1']:.2e}",
         f"r(8)={step['ratio_at_8']:.2f}")

    analytics = bench_analytics(model, n_sim, max_err)
    emit("paged.analytics_rel_err", f"{analytics['rel_err']:.3f}",
         f"corrected system time vs DES, envelope {max_err:.0%}")

    bpt = bench_bytes_per_token(cfg, GRID)
    emit("paged.int8_bytes_per_token", f"{bpt['int8_bytes_per_token']:.1f}",
         f"f32={bpt['f32_bytes_per_token']:.1f}, "
         f"{bpt['compression']:.2f}x")

    wall_s = time.perf_counter() - t_start
    payload = {
        "grid": GRID,
        "mode": "smoke" if args.smoke else "full",
        "n_requests": n_requests,
        "wall_s": wall_s,
        "occupancy": occ,
        "step_latency": step,
        "analytics": analytics,
        "bytes_per_token": bpt,
    }
    with open(args.json_out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    emit("paged.wall_s", f"{wall_s:.1f}", "")
    if args.smoke and args.budget_s is not None:
        assert wall_s <= args.budget_s, (
            f"smoke bench took {wall_s:.1f}s > budget {args.budget_s}s")


if __name__ == "__main__":
    main()
