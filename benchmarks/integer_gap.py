"""Sec III-E: integer-projection quality — the eq-39/40/41 sandwich, plus
the beyond-paper coordinate refinement, across operating points."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import (ServerParams, Problem, paper_problem, sandwich,
                        solve, solve_fixed_point)

from .common import emit
from repro.compat import enable_x64


def main() -> None:
    base = paper_problem()
    for lam in (0.05, 0.1, 0.2, 0.4):
        prob = Problem(tasks=base.tasks,
                       server=ServerParams(lam, 30.0, 32768.0))
        sol = solve(prob)
        with enable_x64():
            s = sandwich(prob, jnp.asarray(sol.lengths_cont))
        gap_round = s["J_continuous"] - s["J_int_round"]
        gap_bound = s["J_continuous"] - s["J_bar_lower_bound"]
        emit(f"integer.lam_{lam}.J_cont", f"{s['J_continuous']:.6f}", "")
        emit(f"integer.lam_{lam}.round_gap", f"{gap_round:.2e}",
             f"bound_gap={gap_bound:.2e}")
        assert s["J_continuous"] >= s["J_int_exhaustive"] >= \
            s["J_int_round"] >= s["J_bar_lower_bound"] - 1e-12
        emit(f"integer.lam_{lam}.sandwich_holds", True, "")


if __name__ == "__main__":
    main()
