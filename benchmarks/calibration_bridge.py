"""Close the loop: dry-run roofline -> (t0, c) recalibration -> re-solve.

The paper calibrated t_k(l) = t0_k + c_k l on an A100. Our TPU substrate
changes the service constants; the §Perf serving fix changes them again.
This benchmark rebuilds the allocation problem with service constants
scaled by the measured decode step time (qwen3-8b, the paper's model) for
(a) the paper-faithful baseline engine and (b) the optimized engine
(kv_repeat=2), and shows what the queueing-aware allocator does with the
recovered slack: budgets and utility both rise.
"""
from __future__ import annotations

import json
import pathlib

import numpy as np

from repro.core import ServerParams, Problem, TaskSet, paper_problem, solve

from .common import emit


def _dominant(path):
    r = json.load(open(path))["roofline"]
    return max(r["compute_s"], r["memory_s"], r["collective_s"])


def main() -> None:
    res = pathlib.Path("results")
    base_p = res / "roofline" / "qwen3-8b__decode_32k__pod__roofline.json"
    opt_p = res / "perf" / "qwen3-8b__decode_32k__pod__roofline__kvrep2.json"
    if not (base_p.exists() and opt_p.exists()):
        emit("bridge.note", "missing-artifacts", "run the dry-run sweeps")
        return
    # decode_32k serves 128 concurrent streams one token per step
    c_base = _dominant(base_p)          # s per token per stream batch
    c_opt = _dominant(opt_p)
    emit("bridge.decode_step_s.baseline", f"{c_base:.4f}", "per 128-stream step")
    emit("bridge.decode_step_s.optimized", f"{c_opt:.4f}",
         f"gain={c_base / c_opt:.1f}x")

    paper = paper_problem()
    mean_paper_c = float(np.mean(np.asarray(paper.tasks.c)))
    for label, step_s in (("baseline", c_base), ("optimized", c_opt)):
        scale = step_s / mean_paper_c
        tasks = TaskSet(names=paper.tasks.names, A=paper.tasks.A,
                        b=paper.tasks.b, D=paper.tasks.D,
                        t0=np.asarray(paper.tasks.t0) * scale,
                        c=np.asarray(paper.tasks.c) * scale,
                        pi=paper.tasks.pi)
        # keep the same utilization-pressure as the paper: scale lambda
        # inversely so lam * E[S(0)] matches the paper's operating point
        prob = Problem(tasks=tasks,
                       server=ServerParams(paper.server.lam / scale,
                                           paper.server.alpha,
                                           paper.server.l_max))
        sol = solve(prob)
        emit(f"bridge.{label}.budgets",
             "|".join(str(int(v)) for v in sol.lengths_int),
             f"J={sol.value_cont:.4f}")
    # and at FIXED arrival rate, the faster engine buys budget headroom:
    scale_b = c_base / mean_paper_c
    scale_o = c_opt / mean_paper_c
    lam_fixed = paper.server.lam / scale_b      # stable under the baseline
    js = {}
    for label, scale in (("baseline", scale_b), ("optimized", scale_o)):
        tasks = TaskSet(names=paper.tasks.names, A=paper.tasks.A,
                        b=paper.tasks.b, D=paper.tasks.D,
                        t0=np.asarray(paper.tasks.t0) * scale,
                        c=np.asarray(paper.tasks.c) * scale,
                        pi=paper.tasks.pi)
        prob = Problem(tasks=tasks, server=ServerParams(
            lam_fixed, paper.server.alpha, paper.server.l_max))
        sol = solve(prob)
        js[label] = sol.value_cont
        emit(f"bridge.fixed_lam.{label}.budgets",
             "|".join(str(int(v)) for v in sol.lengths_int),
             f"J={sol.value_cont:.4f}")
    emit("bridge.fixed_lam.utility_gain",
         f"{js['optimized'] - js['baseline']:.4f}",
         "J units bought by the §Perf serving fix at equal load")
    emit("bridge.note", "single-stream-M/G/1",
         "a TPU pod serves 128 concurrent streams; dividing c by the "
         "batch concurrency or using the M/G/c extension recovers "
         "paper-scale budgets (see serve.mgc.* in serving_bench)")


if __name__ == "__main__":
    main()
