"""Closed-loop replay benchmark: the allocator<->engine digital twin.

Four lanes, each exercising one claim of ``serving.replay``:

* **virtual** — the full closed loop (estimate -> re-solve -> serve) over a
  long stationary trace through the virtual plant; times the loop and
  checks the converged budgets land next to the oracle solution that a
  clairvoyant solver (true lambda / pi / latency curve) produces.
* **crn** — fixed-policy virtual replay against the batched Lindley DES on
  common random numbers at rho in {0.6, 0.9}: per-query waits must agree
  to float round-off, and the P-K prediction must fall inside the DES 95%
  CI over the seed batch (millions of simulated queries; the acceptance
  gate of the twin's queueing kernel).
* **drift** — piecewise-stationary lambda and pi shifts; scores end-of-
  segment tracking error of the online estimators and confirms the
  deployed budgets actually move when the operating point does.
* **engine** — the REAL chunked-scan decode path (reduced model): per-
  request wall-clock services replayed through the same Lindley recursion,
  measured accuracy/system time compared against the twin's own P-K
  prediction at its estimated operating point via
  ``sweeps.frontier_comparison`` (zero oracle latency parameters).

    PYTHONPATH=src python -m benchmarks.replay_bench [--smoke]

Writes ``BENCH_replay.json`` (``--json-out`` to relocate). The committed
artifact is a full run; CI runs ``--smoke`` and gates it against the
committed numbers through ``benchmarks/report.py --check``.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import paper_problem
from repro.core.allocator import solve
from repro.queueing_sim import (Segment, ci95, generate_drift_trace,
                                generate_streams, trace_from_stream_batch)
from repro.queueing_sim.batched import lindley_numpy
from repro.serving import ReplayConfig, ReplayHarness
from repro.sweeps import frontier_comparison, saturation_rate

from .common import emit


def virtual_lane(prob, n_queries: int) -> dict:
    """Closed loop on a stationary trace; converged budgets vs oracle."""
    lam = prob.server.lam
    trace = generate_drift_trace(prob.tasks, [Segment(n_queries, lam)],
                                 seed=7)
    h = ReplayHarness(prob, ReplayConfig(block_size=512))
    t0 = time.perf_counter()
    res = h.run_virtual(trace)
    elapsed = time.perf_counter() - t0
    oracle = np.asarray(solve(prob).lengths_int, dtype=np.int64)
    gap = int(np.max(np.abs(res.final_budgets - oracle)))
    est = res.estimator_state
    lam_acc = 1.0 - abs(est["lam"] - lam) / lam
    c_rel = float(np.max(np.abs(np.asarray(est["c"])
                                - np.asarray(prob.tasks.c))
                         / np.asarray(prob.tasks.c)))
    m = res.measured()
    pred = h.predicted(lam)
    # tail scoring: measured wait percentiles (exact, from the report)
    # vs the M/G/1 exponential-tail prediction at the deployed budgets
    rep = res.report(prob)
    comp = frontier_comparison(
        [m["accuracy_prob"]], [m["mean_system_time"]],
        [pred["accuracy"]], [pred["mean_system_time"]],
        measured_percentiles=rep.wait_percentiles,
        predicted_percentiles=pred["wait_percentiles"],
        drift=rep.drift)
    emit("replay.virtual.queries_per_s", f"{n_queries / elapsed:.0f}",
         f"n={n_queries}, resolves={res.n_resolves}")
    emit("replay.virtual.budget_linf_gap", gap,
         f"final={list(res.final_budgets)}, oracle={list(oracle)}")
    emit("replay.virtual.lam_accuracy", f"{lam_acc:.4f}",
         f"lam_hat={est['lam']:.5f}, true={lam}")
    emit("replay.virtual.p90_wait_rel_gap",
         f"{comp['rel_gap_percentiles'].get('p90', 0.0):.3f}",
         f"measured={rep.wait_percentiles.get('p90', 0.0):.3f}s, "
         f"exp-tail={pred['wait_percentiles'].get('p90', 0.0):.3f}s")
    return {
        "n_queries": n_queries,
        "elapsed_s": elapsed,
        "queries_per_s": n_queries / elapsed,
        "n_resolves": res.n_resolves,
        "final_budgets": [int(v) for v in res.final_budgets],
        "oracle_budgets": [int(v) for v in oracle],
        "budget_linf_gap": gap,
        "measured_system_time": m["mean_system_time"],
        "predicted_system_time": pred["mean_system_time"],
        "measured_accuracy_prob": m["accuracy_prob"],
        "predicted_accuracy": pred["accuracy"],
        "measured_wait_percentiles": rep.wait_percentiles,
        "predicted_wait_percentiles": pred["wait_percentiles"],
        "rel_gap_percentiles": comp["rel_gap_percentiles"],
        "estimation": {
            "lam_hat": est["lam"], "lam_true": lam,
            "lam_accuracy": lam_acc,
            "c_max_rel_err": c_rel,
            "pi_linf_err": float(np.max(np.abs(
                np.asarray(est["pi"]) - np.asarray(prob.tasks.pi)))),
        },
    }


def crn_lane(prob, rhos, n_seeds: int, n_queries: int) -> dict:
    """Fixed-policy replay vs batched DES on common random numbers."""
    lengths = np.asarray(solve(prob).lengths_int, dtype=np.int64)
    t0 = np.asarray(prob.tasks.t0)
    c = np.asarray(prob.tasks.c)
    es = float(np.sum(np.asarray(prob.tasks.pi) * (t0 + c * lengths)))
    out = {}
    for rho in rhos:
        lam = rho / es
        batch = generate_streams(prob.tasks, lam, n_seeds, n_queries,
                                 seed=11)
        # DES: every replicate in one vectorized Lindley pass
        s = t0[batch.types] + c[batch.types] * lengths[batch.types]
        start, _ = lindley_numpy(batch.arrivals, s)
        waits = start - batch.arrivals
        warm = int(0.25 * n_queries)
        per_seed = waits[:, warm:].mean(axis=1)
        des_mean = float(per_seed.mean())
        des_ci = float(ci95(per_seed))
        # replay: replicate 0 through the harness (identical randomness)
        res = ReplayHarness(prob).run_virtual(
            trace_from_stream_batch(batch, 0), fixed_lengths=lengths)
        max_diff = float(np.max(np.abs(res.waits - waits[0])))
        es2 = float(np.sum(np.asarray(prob.tasks.pi)
                           * (t0 + c * lengths) ** 2))
        pk_wait = lam * es2 / (2 * (1 - lam * es))
        in_ci = bool(abs(pk_wait - des_mean) <= des_ci)
        emit(f"replay.crn.rho{rho}.max_abs_wait_diff", f"{max_diff:.2e}",
             f"pk={pk_wait:.3f} vs des={des_mean:.3f}+-{des_ci:.3f}")
        assert max_diff < 1e-8, \
            f"replay/DES CRN divergence at rho={rho}: {max_diff}"
        out[str(rho)] = {
            "lam": lam, "max_abs_wait_diff": max_diff,
            "des_mean_wait": float(des_mean), "des_ci95": float(des_ci),
            "pk_mean_wait": float(pk_wait), "pk_in_ci": in_ci,
            "n_seeds": n_seeds, "n_queries": n_queries,
        }
    return out


def drift_lane(prob, n_per_segment: int) -> dict:
    """Piecewise-stationary lambda and pi shifts; end-of-segment tracking."""
    lam0 = prob.server.lam
    sat = saturation_rate(prob.tasks)
    n = prob.tasks.n_tasks
    pi_shift = np.full(n, 0.4 / (n - 1))
    pi_shift[1] = 0.6                      # mass onto GSM8K
    segments = [
        Segment(n_per_segment, lam0),
        Segment(n_per_segment, min(3.0 * lam0, 0.5 * sat)),
        Segment(n_per_segment, lam0, pi=tuple(pi_shift)),
    ]
    trace = generate_drift_trace(prob.tasks, segments, seed=13)
    cfg = ReplayConfig(block_size=256, est_halflife=512.0)
    h = ReplayHarness(prob, cfg)
    res = h.run_virtual(trace)
    seg_rows = []
    budgets_per_seg = []
    for s_idx, seg in enumerate(segments):
        # last block whose requests all belong to this segment
        lo = s_idx * n_per_segment
        hi = lo + n_per_segment
        blk = [b for i, b in enumerate(res.blocks)
               if (i + 1) * cfg.block_size <= hi] or [res.blocks[0]]
        est = blk[-1].estimator
        rel = abs(est["lam"] - seg.lam) / seg.lam
        budgets_per_seg.append(blk[-1].budgets)
        seg_rows.append({
            "lam_true": seg.lam, "lam_hat_end": est["lam"],
            "lam_rel_err_end": rel,
            "pi_hat_end": est["pi"],
        })
        emit(f"replay.drift.seg{s_idx}.lam_rel_err", f"{rel:.3f}",
             f"true={seg.lam:.4f}, hat={est['lam']:.4f}")
    moved = bool(np.any(budgets_per_seg[0] != budgets_per_seg[1]))
    emit("replay.drift.budgets_moved", moved,
         f"seg0={list(budgets_per_seg[0])}, seg1={list(budgets_per_seg[1])}")
    return {"segments": seg_rows, "budgets_moved": moved,
            "budgets_per_segment": [[int(v) for v in b]
                                    for b in budgets_per_seg],
            "n_resolves": res.n_resolves}


def engine_lane(prob, n_decodes: int, rho_target: float = 0.6) -> dict:
    """Real chunked-scan decodes through the twin; measured point vs the
    twin's own P-K prediction at its ESTIMATED operating point."""
    import jax

    from repro.configs import get_config
    from repro.core import Problem, ServerParams
    from repro.models import init_params, reduced
    from repro.serving.engine import DecodeEngine

    cfg = reduced(get_config("qwen3-0.6b"), d_model=128)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = DecodeEngine(cfg, params, cache_capacity=128, chunk=16)

    l_max = 48.0
    small = Problem(tasks=prob.tasks,
                    server=ServerParams(prob.server.lam, 2.0, l_max))
    rcfg = ReplayConfig(block_size=max(16, n_decodes // 8), l_init=16,
                        est_halflife=128.0, explore_frac=0.25,
                        explore_min_spread=8, min_services=8)
    h = ReplayHarness(small, rcfg, engine=eng)

    # probe the wall-clock service scale (post-compile) to pick an arrival
    # rate at the target utilization — no oracle latency curve involved
    prompt = (np.arange(8) % 97 + 1).astype(np.int32)[None, :]
    eng.generate(prompt, [rcfg.l_init], max_extra_tokens=0)
    probes = []
    for _ in range(3):
        w0 = time.perf_counter()
        eng.generate(prompt, [rcfg.l_init], max_extra_tokens=0)
        probes.append(time.perf_counter() - w0)
    es_probe = float(np.median(probes))
    lam = rho_target / es_probe
    trace = generate_drift_trace(prob.tasks, [Segment(n_decodes, lam)],
                                 seed=17, prompt_len_range=(8, 8))
    t0 = time.perf_counter()
    res = h.run_engine(trace, prompt_len=8, max_extra_tokens=0)
    elapsed = time.perf_counter() - t0
    m = res.measured(warmup_frac=0.25)
    est = res.estimator_state
    # the twin's prediction: P-K at the ESTIMATED moments + the analytic
    # accuracy curve at the deployed budgets (no plant parameters)
    pred_wait = est["pk_wait"]
    pred_sys = pred_wait + est["es"]
    A = np.asarray(small.tasks.A)
    b = np.asarray(small.tasks.b)
    D = np.asarray(small.tasks.D)
    pi = np.asarray(est["pi"])
    lb = res.final_budgets
    pred_acc = float(np.sum(pi * (A * (1 - np.exp(-b * lb)) + D)))
    comp = frontier_comparison(
        [m["accuracy_prob"]], [m["mean_system_time"]],
        [pred_acc], [pred_sys], ci_system_time=[m["ci95_system_time"]])
    tok = int(res.budgets.sum())
    emit("replay.engine.tok_per_s", f"{tok / elapsed:.0f}",
         f"decodes={n_decodes}, real chunked-scan services")
    emit("replay.engine.rel_gap_system_time",
         f"{comp['max_rel_gap_system_time']:.3f}",
         f"measured={m['mean_system_time']:.3f}s, twin={pred_sys:.3f}s")
    return {
        "n_decodes": n_decodes, "elapsed_s": elapsed,
        "tokens_generated": tok, "tok_per_s": tok / elapsed,
        "lam": lam, "rho_target": rho_target,
        "n_resolves": res.n_resolves,
        "final_budgets": [int(v) for v in res.final_budgets],
        "measured": m,
        "predicted_system_time": pred_sys,
        "predicted_accuracy": pred_acc,
        "rel_gap_system_time": comp["max_rel_gap_system_time"],
        "gap_accuracy": comp["max_gap_accuracy"],
        "ci_covered": bool(comp["covered"][0]),
        "estimator": {"lam_hat": est["lam"], "es_hat": est["es"],
                      "t0_hat": est["t0"], "c_hat": est["c"]},
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small lanes + relaxed floors (CI)")
    ap.add_argument("--json-out", default="BENCH_replay.json")
    args = ap.parse_args(argv)

    if args.smoke:
        n_virtual, n_seeds, n_crn, n_seg, n_dec = 20_000, 8, 20_000, 4000, 96
    else:
        n_virtual, n_seeds, n_crn, n_seg, n_dec = 200_000, 32, 60_000, \
            20_000, 600

    prob = paper_problem()
    out = {
        "mode": "smoke" if args.smoke else "full",
        "virtual": virtual_lane(prob, n_virtual),
        "crn": crn_lane(prob, (0.6, 0.9), n_seeds, n_crn),
        "drift": drift_lane(prob, n_seg),
        "engine": engine_lane(prob, n_dec),
    }
    out["estimation"] = out["virtual"]["estimation"]

    lam_floor = 0.6 if args.smoke else 0.8
    assert out["estimation"]["lam_accuracy"] >= lam_floor, \
        f"lambda estimation accuracy {out['estimation']['lam_accuracy']:.3f}"
    gap_cap = 32 if args.smoke else 16
    assert out["virtual"]["budget_linf_gap"] <= gap_cap, \
        f"converged budgets {out['virtual']['budget_linf_gap']} tokens off"
    assert out["drift"]["budgets_moved"], "budgets never reacted to drift"
    assert out["drift"]["segments"][-1]["lam_rel_err_end"] < 0.35, \
        "post-drift lambda tracking too slow"

    with open(args.json_out, "w") as f:
        json.dump(out, f, indent=1)
    emit("replay.artifact", args.json_out, out["mode"])


if __name__ == "__main__":
    main()
