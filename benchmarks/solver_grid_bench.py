"""Micro-benchmark: vmapped grid solver vs the scalar solver loop.

Workload: a ``(lambda x alpha x l_max)`` operating grid on the paper's
calibrated instance — the capacity-planning sweep every benchmark used to
run one scalar ``core.allocator.solve`` per cell for. Full mode solves a
>= 100-cell grid on the device path, re-solves a scalar reference subset,
checks per-cell agreement (continuous optima to 1e-6, identical integer
budgets), and measures cells/sec both ways. Acceptance: the grid path is
>= 10x the scalar loop's throughput.

    PYTHONPATH=src python -m benchmarks.solver_grid_bench [--smoke]

``--smoke`` shrinks the grid (12 cells, 4-cell scalar reference) and
enforces a wall-clock budget, for CI. Either mode writes a
``BENCH_solver_grid.json`` artifact (``--json-out`` to relocate) recording
the throughputs for the perf trajectory.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import ServerParams, Problem, paper_problem, solve
from repro.sweeps import solve_grid

from .common import emit


def _grid(smoke: bool):
    if smoke:
        lams = np.linspace(0.05, 0.5, 3)
        alphas = np.array([15.0, 30.0])
        lmaxs = np.array([1024.0, 32768.0])
    else:
        lams = np.linspace(0.05, 0.5, 10)
        alphas = np.array([10.0, 20.0, 30.0, 45.0, 60.0])
        lmaxs = np.array([1024.0, 32768.0])
    return np.meshgrid(lams, alphas, lmaxs, indexing="ij")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grid + wall-clock budget (CI)")
    ap.add_argument("--budget-s", type=float, default=60.0,
                    help="smoke-mode wall-clock budget for the grid solve")
    ap.add_argument("--json-out", default="BENCH_solver_grid.json",
                    help="perf-trajectory artifact path")
    ap.add_argument("--scalar-cells", type=int, default=None,
                    help="scalar reference subset size (default 4 smoke / "
                         "12 full)")
    args = ap.parse_args(argv)

    prob0 = paper_problem()
    tasks = prob0.tasks
    lam_g, alpha_g, lmax_g = _grid(args.smoke)
    n_cells = lam_g.size
    emit("solver_grid_bench.grid", "x".join(map(str, lam_g.shape)),
         f"{n_cells} cells")

    # --- vmapped grid path: cold (includes trace+compile) and steady state
    t0 = time.perf_counter()
    sol = solve_grid(tasks, lam_g, alpha_g, lmax_g)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    sol = solve_grid(tasks, lam_g, alpha_g, lmax_g)
    t_warm = time.perf_counter() - t0
    assert bool(np.all(sol.stable)), "grid produced unstable cells"

    # --- scalar reference loop over a subset, extrapolated to cells/sec ---
    n_ref = args.scalar_cells or (4 if args.smoke else 12)
    flat = sol.ravel()
    ref_idx = np.linspace(0, n_cells - 1, n_ref).astype(int)
    worst_cont, worst_int = 0.0, 0
    t0 = time.perf_counter()
    for i in ref_idx:
        s = solve(Problem(tasks=tasks,
                          server=ServerParams(float(flat.lam[i]),
                                              float(flat.alpha[i]),
                                              float(flat.l_max[i]))))
        worst_cont = max(worst_cont, float(
            np.max(np.abs(s.lengths_cont - flat.lengths_cont[i]))))
        worst_int = max(worst_int, int(
            np.max(np.abs(s.lengths_int - flat.lengths_int[i]))))
    t_scalar_ref = time.perf_counter() - t0
    scalar_cps = n_ref / max(t_scalar_ref, 1e-12)
    grid_cps_warm = n_cells / max(t_warm, 1e-12)
    grid_cps_cold = n_cells / max(t_cold, 1e-12)
    speedup = grid_cps_warm / max(scalar_cps, 1e-12)

    emit("solver_grid_bench.agree_cont", f"{worst_cont:.2e}",
         f"max |l*_grid - l*_scalar| over {n_ref} reference cells")
    emit("solver_grid_bench.agree_int", worst_int,
         "max integer-budget deviation (must be 0)")
    emit("solver_grid_bench.scalar_cells_per_s", f"{scalar_cps:.2f}",
         f"{n_ref} scalar solves in {t_scalar_ref:.2f}s")
    emit("solver_grid_bench.grid_cells_per_s", f"{grid_cps_warm:.1f}",
         f"{n_cells} cells in {t_warm:.3f}s (steady state)")
    emit("solver_grid_bench.grid_cells_per_s_cold", f"{grid_cps_cold:.1f}",
         f"incl. trace+compile ({t_cold:.2f}s)")
    emit("solver_grid_bench.speedup", f"{speedup:.1f}",
         "grid vs scalar loop, cells/sec")
    emit("solver_grid_bench.speedup_ok", bool(speedup >= 10.0),
         "acceptance: >= 10x over the scalar solver loop")

    assert worst_cont < 1e-6, (
        f"grid/scalar continuous optima disagree: {worst_cont:.2e}")
    assert worst_int == 0, "grid/scalar integer budgets disagree"
    if not args.smoke:
        assert n_cells >= 100, "full-mode grid must cover >= 100 cells"
        assert speedup >= 10.0, (
            f"grid path only {speedup:.1f}x the scalar loop")
    if args.smoke:
        assert t_warm <= args.budget_s, (
            f"smoke budget blown: {t_warm:.2f}s > {args.budget_s}s")

    artifact = {
        "bench": "solver_grid",
        "mode": "smoke" if args.smoke else "full",
        "grid_shape": list(lam_g.shape),
        "n_cells": int(n_cells),
        "n_scalar_reference_cells": int(n_ref),
        "scalar_cells_per_s": scalar_cps,
        "grid_cells_per_s": grid_cps_warm,
        "grid_cells_per_s_cold": grid_cps_cold,
        "speedup_vs_scalar": speedup,
        "grid_solve_s_cold": t_cold,
        "grid_solve_s_warm": t_warm,
        "scalar_reference_s": t_scalar_ref,
        "max_abs_cont_deviation": worst_cont,
        "max_int_deviation": int(worst_int),
        "fp_converged_cells": int(np.sum(flat.fp_converged)),
        "pga_fallback_cells": int(np.sum(flat.used_pga)),
    }
    with open(args.json_out, "w") as f:
        json.dump(artifact, f, indent=2)
        f.write("\n")
    emit("solver_grid_bench.artifact", args.json_out, "")


if __name__ == "__main__":
    main()
