"""Sec III-C/D: solver convergence behaviour.

Fixed-point iterations vs PGA (global-step and backtracking) across load,
plus the Lemma 2 certificate values — documenting the reproduction finding
that the paper-form certificate is vacuous (always > 1) while the map
empirically contracts. The per-load diagnostics (iterations, KKT
residuals, both certificate variants, PGA-fallback mask) now also come out
of ONE vmapped grid solve (``repro.sweeps.solve_grid``), cross-checked
against the scalar solvers below."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import (ServerParams, Problem, contraction_certificate,
                        paper_problem, safe_step_size, solve_fixed_point,
                        solve_pga, solve_pga_backtracking)
from repro.core.fixed_point import empirical_contraction_estimate
from repro.sweeps import reference_check, solve_grid

from .common import emit
from repro.compat import enable_x64

LAMS = (0.05, 0.1, 0.3)


def main() -> None:
    base = paper_problem()
    sp = base.server
    grid = solve_grid(base.tasks, np.asarray(LAMS), sp.alpha, sp.l_max)
    reference_check(base.tasks, grid)
    for i, lam in enumerate(LAMS):
        emit(f"conv.grid.lam_{lam}.fp_iters", int(grid.fp_iterations[i]),
             f"converged={bool(grid.fp_converged[i])}, "
             f"kkt={grid.kkt_residual[i]:.2e}, "
             f"pga_fallback={bool(grid.used_pga[i])}")
        emit(f"conv.grid.lam_{lam}.L_inf_slab",
             f"{grid.contraction_Linf_slab[i]:.3g}",
             "Lemma 2 certificate, batched")
    for lam in LAMS:
        prob = Problem(tasks=base.tasks,
                       server=ServerParams(lam, 30.0, 32768.0))
        with enable_x64():
            fp = solve_fixed_point(prob, tol=1e-10)
            pgb = solve_pga_backtracking(prob, tol=1e-10)
            emit(f"conv.lam_{lam}.fp_iters", int(fp.iterations),
                 f"converged={bool(fp.converged)}")
            emit(f"conv.lam_{lam}.pga_bt_iters", int(pgb.iterations),
                 f"converged={bool(pgb.converged)}")
            cert = float(contraction_certificate(prob))
            cert_slab = float(contraction_certificate(prob, 5e-2))
            emp = float(empirical_contraction_estimate(prob, n_samples=24))
            # local modulus at the fixed point = asymptotic FP rate
            from repro.core.fixed_point import fixed_point_map
            jac = jax.jacfwd(lambda v: fixed_point_map(prob, v))(fp.lengths)
            local = float(np.max(np.sum(np.abs(np.asarray(jac)), axis=1)))
            emit(f"conv.lam_{lam}.L_inf_paper", f"{cert:.3g}",
                 "eq26; >1 always (vacuous-by-construction)")
            emit(f"conv.lam_{lam}.L_inf_slab", f"{cert_slab:.3g}", "")
            emit(f"conv.lam_{lam}.slab_sup_modulus", f"{emp:.3g}",
                 "sampled sup ||J_lhat||_inf over the slab")
            emit(f"conv.lam_{lam}.local_modulus_at_lstar", f"{local:.3g}",
                 "asymptotic FP rate (<1 explains fast convergence)")
            eta = float(safe_step_size(prob))
            emit(f"conv.lam_{lam}.safe_eta", f"{eta:.3g}", "eq38 (slab)")
    # plain PGA with the guaranteed step on the paper instance: the bound is
    # conservative, so measure the J-gap after a fixed budget, not residuals
    from repro.core import objective
    prob = paper_problem()
    with enable_x64():
        ref = solve_fixed_point(prob, tol=1e-12)
        pg = solve_pga(prob, tol=1e-7, max_iters=100_000)
        jgap = float(objective(prob, ref.lengths)
                     - objective(prob, pg.lengths))
    emit("conv.plain_pga_100k_iters_J_gap", f"{jgap:.2e}",
         f"eta={float(pg.eta):.3g} (guaranteed step; conservative)")


if __name__ == "__main__":
    main()
