"""Paper Fig 4: J(l) as a function of the GSM8K budget with all other
budgets at optimum — unimodal with maximizer ~ 340; plus the eq-41 lower
bound and DES cross-check points.

Device-resident end to end: the base optimum comes from the vmapped grid
solver (scalar ``solve`` as cross-checked reference), the whole J / eq-41
budget sweep is ONE batched ``objective`` / ``rounding_lower_bound`` call
over a ``[G, N]`` stack of allocations, the DES cross-check is one batched
Lindley sweep, and the beyond-paper (lambda x alpha) sensitivity now
re-SOLVES the optimum per cell through ``solve_grid`` (12 operating points,
one device pass) in addition to reweighting the common-random-number
simulations.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.compat import enable_x64
from repro.core import objective, paper_problem, rounding_lower_bound
from repro.queueing_sim import sweep
from repro.sweeps import reference_check, solve_grid

from .common import emit

GSM8K = 1


def main() -> None:
    prob = paper_problem()
    sp = prob.server
    gsol = solve_grid(prob.tasks, sp.lam, sp.alpha, sp.l_max)
    reference_check(prob.tasks, gsol)
    base = np.asarray(gsol.lengths_cont)

    grid = np.arange(0, 1001, 25)
    stack = np.repeat(base[None, :], grid.shape[0], axis=0)
    stack[:, GSM8K] = grid
    with enable_x64():
        vals = np.asarray(objective(prob, jnp.asarray(stack)))
        bounds = np.asarray(rounding_lower_bound(prob, jnp.asarray(stack)))
    argmax = grid[int(np.argmax(vals))]
    emit("fig4.argmax_gsm8k", int(argmax), f"paper~340, J={vals.max():.4f}")
    # unimodality: strictly increasing then strictly decreasing
    d = np.diff(vals)
    switch = int(np.argmax(d < 0))
    unimodal = bool(np.all(d[:switch] > 0) and np.all(d[switch:] < 0))
    emit("fig4.unimodal", unimodal, "")
    emit("fig4.bound_below_J", bool(np.all(bounds <= vals + 1e-9)),
         "eq41 holds on the sweep")

    # DES cross-check over the whole grid in one batched call
    policies = {}
    for g in grid:
        l = np.round(base.copy())
        l[GSM8K] = g
        policies[f"gsm8k_{int(g)}"] = l
    res = sweep(prob, policies, lams=[sp.lam], n_seeds=16,
                n_queries=10_000, seed=1)
    des_vals = res.objective[0]
    des_argmax = int(grid[int(np.argmax(des_vals))])
    emit("fig4.des_argmax_gsm8k", des_argmax,
         f"analytic argmax {int(argmax)}")
    for g in (0, 200, 600, 1000):
        p = list(res.policy_names).index(f"gsm8k_{g}")
        jv = vals[int(np.argmax(grid == g))]
        emit(f"fig4.J_des.gsm8k_{g}", f"{des_vals[p]:.4f}",
             f"+-{res.ci_objective[0, p]:.4f}, analytic={jv:.4f}")
    emit("fig4.des_within_ci",
         bool(np.all(np.abs(des_vals - vals) <= 4 * res.ci_objective[0]
                     + 0.05)),
         "DES grid tracks analytic J")

    # Beyond paper: (lambda x alpha) sensitivity. The grid solver re-solves
    # the full optimum at every operating point in one device pass...
    lams = np.array([0.05, 0.1, 0.15])
    alphas = np.array([15.0, 30.0, 60.0])
    sens = solve_grid(prob.tasks, lams[:, None], alphas[None, :], sp.l_max)
    for i, lam in enumerate(lams):
        for j, alpha in enumerate(alphas):
            emit(f"fig4.lstar_gsm8k.lam_{lam}.alpha_{int(alpha)}",
                 f"{sens.lengths_cont[i, j, GSM8K]:.1f}",
                 f"J*={sens.value_cont[i, j]:.4f}, "
                 f"rho={sens.rho_cont[i, j]:.3f}")
    # ...and the DES argmax over the FIXED fig4 policy stack rides on the
    # same common-random-number simulations via post-hoc reweighting.
    for lam in lams:
        r = sweep(prob, policies, lams=[float(lam)], n_seeds=8,
                  n_queries=10_000, seed=2)
        for alpha in alphas:
            j = r.objective_at(float(alpha))[0]
            emit(f"fig4.argmax.lam_{lam}.alpha_{int(alpha)}",
                 int(grid[int(np.argmax(j))]),
                 f"J={j.max():.4f}")


if __name__ == "__main__":
    main()
