"""Paper Fig 4: J(l) as a function of the GSM8K budget with all other
budgets at optimum — unimodal with maximizer ~ 340; plus the eq-41 lower
bound and DES cross-check points.

The DES columns run on the batched Lindley path: the *entire* budget grid
(41 policies x 16 seeds x 10k queries = 6.56M simulated queries) is one
vectorized call, and a beyond-paper (lambda x alpha) sensitivity grid rides
on the same simulations via post-hoc objective reweighting.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import objective, paper_problem, rounding_lower_bound, solve
from repro.queueing_sim import sweep

from .common import emit
from repro.compat import enable_x64

GSM8K = 1


def main() -> None:
    prob = paper_problem()
    sol = solve(prob)
    base = np.asarray(sol.lengths_cont)

    grid = np.arange(0, 1001, 25)
    with enable_x64():
        vals = []
        bounds = []
        for g in grid:
            l = base.copy()
            l[GSM8K] = g
            vals.append(float(objective(prob, jnp.asarray(l))))
            bounds.append(float(rounding_lower_bound(prob, jnp.asarray(l))))
    vals = np.array(vals)
    argmax = grid[int(np.argmax(vals))]
    emit("fig4.argmax_gsm8k", int(argmax), f"paper~340, J={vals.max():.4f}")
    # unimodality: strictly increasing then strictly decreasing
    d = np.diff(vals)
    switch = int(np.argmax(d < 0))
    unimodal = bool(np.all(d[:switch] > 0) and np.all(d[switch:] < 0))
    emit("fig4.unimodal", unimodal, "")
    emit("fig4.bound_below_J", bool(np.all(np.array(bounds) <= vals + 1e-9)),
         "eq41 holds on the sweep")

    # DES cross-check over the whole grid in one batched call
    policies = {}
    for g in grid:
        l = np.round(base.copy())
        l[GSM8K] = g
        policies[f"gsm8k_{int(g)}"] = l
    res = sweep(prob, policies, lams=[prob.server.lam], n_seeds=16,
                n_queries=10_000, seed=1)
    des_vals = res.objective[0]
    des_argmax = int(grid[int(np.argmax(des_vals))])
    emit("fig4.des_argmax_gsm8k", des_argmax,
         f"analytic argmax {int(argmax)}")
    for g in (0, 200, 600, 1000):
        p = list(res.policy_names).index(f"gsm8k_{g}")
        jv = vals[int(np.argmax(grid == g))]
        emit(f"fig4.J_des.gsm8k_{g}", f"{des_vals[p]:.4f}",
             f"+-{res.ci_objective[0, p]:.4f}, analytic={jv:.4f}")
    emit("fig4.des_within_ci",
         bool(np.all(np.abs(des_vals - vals) <= 4 * res.ci_objective[0]
                     + 0.05)),
         "DES grid tracks analytic J")

    # Beyond paper: (lambda x alpha) sensitivity of the argmax. One batched
    # call per lambda; the alpha axis reuses the simulations (J is affine in
    # alpha given realized accuracy/delay).
    for lam in (0.05, 0.1, 0.15):
        r = sweep(prob, policies, lams=[lam], n_seeds=8, n_queries=10_000,
                  seed=2)
        for alpha in (15.0, 30.0, 60.0):
            j = r.objective_at(alpha)[0]
            emit(f"fig4.argmax.lam_{lam}.alpha_{int(alpha)}",
                 int(grid[int(np.argmax(j))]),
                 f"J={j.max():.4f}")


if __name__ == "__main__":
    main()
