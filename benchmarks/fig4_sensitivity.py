"""Paper Fig 4: J(l) as a function of the GSM8K budget with all other
budgets at optimum — unimodal with maximizer ~ 340; plus the eq-41 lower
bound and DES cross-check points."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import objective, paper_problem, rounding_lower_bound, solve
from repro.queueing_sim import generate_stream, simulate

from .common import emit

GSM8K = 1


def main() -> None:
    prob = paper_problem()
    sol = solve(prob)
    base = np.asarray(sol.lengths_cont)

    grid = np.arange(0, 1001, 25)
    with jax.enable_x64(True):
        vals = []
        bounds = []
        for g in grid:
            l = base.copy()
            l[GSM8K] = g
            vals.append(float(objective(prob, jnp.asarray(l))))
            bounds.append(float(rounding_lower_bound(prob, jnp.asarray(l))))
    vals = np.array(vals)
    argmax = grid[int(np.argmax(vals))]
    emit("fig4.argmax_gsm8k", int(argmax), f"paper~340, J={vals.max():.4f}")
    # unimodality: strictly increasing then strictly decreasing
    d = np.diff(vals)
    switch = int(np.argmax(d < 0))
    unimodal = bool(np.all(d[:switch] > 0) and np.all(d[switch:] < 0))
    emit("fig4.unimodal", unimodal, "")
    emit("fig4.bound_below_J", bool(np.all(np.array(bounds) <= vals + 1e-9)),
         "eq41 holds on the sweep")

    # DES cross-check at a few budgets (paper's black circles)
    stream = generate_stream(prob.tasks, prob.server.lam, 10_000, seed=1)
    for g in (0, 200, 340, 600, 1000):
        l = base.copy()
        l[GSM8K] = g
        res = simulate(prob, np.round(l), stream)
        jv = float(objective(prob, jnp.asarray(l)))
        emit(f"fig4.J_des.gsm8k_{g}", f"{res.objective:.4f}",
             f"analytic={jv:.4f}")


if __name__ == "__main__":
    main()
