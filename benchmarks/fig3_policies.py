"""Paper Fig 3: J under uniform allocations {0,100,500} vs the optimal
heterogeneous l*, analytically AND through the DES.

Runs device-resident end to end: the optimum comes from the vmapped grid
solver (``repro.sweeps.solve_grid``; the scalar ``core.allocator.solve``
stays as the cross-checked reference), the analytic J column for all four
policies is one batched ``objective`` call, and the DES column is a single
batched Lindley sweep (all policies x 8 seeds x 10k queries), so it
carries a 95% CI for free.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.compat import enable_x64
from repro.core import objective, paper_problem
from repro.queueing_sim import sweep
from repro.sweeps import reference_check, solve_grid

from .common import emit


def main() -> None:
    prob = paper_problem()
    sp = prob.server
    grid = solve_grid(prob.tasks, sp.lam, sp.alpha, sp.l_max)
    # scalar reference path must agree with the grid cell
    agree = reference_check(prob.tasks, grid)
    emit("fig3.grid_vs_scalar_lstar", f"{agree:.2e}",
         "|l*_grid - l*_scalar|_inf (reference check)")

    policies = {
        "uniform_0": np.zeros(6),
        "uniform_100": np.full(6, 100.0),
        "uniform_500": np.full(6, 500.0),
        "optimal": np.asarray(grid.lengths_int),
    }
    stack = np.stack(list(policies.values()))
    with enable_x64():
        j_analytic_all = np.asarray(objective(prob, jnp.asarray(stack)))
    res = sweep(prob, policies, lams=[sp.lam], n_seeds=8,
                n_queries=10_000, seed=0)
    j_opt = None
    for p, name in enumerate(res.policy_names):
        j_analytic = float(j_analytic_all[p])
        emit(f"fig3.J_analytic.{name}", f"{j_analytic:.4f}", "")
        emit(f"fig3.J_des.{name}", f"{res.objective[0, p]:.4f}",
             f"+-{res.ci_objective[0, p]:.4f}, "
             f"mean_sys={res.mean_system_time[0, p]:.3f}")
        if name == "optimal":
            j_opt = j_analytic
    for p, name in enumerate(res.policy_names):
        if name != "optimal":
            gap = j_opt - float(j_analytic_all[p])
            emit(f"fig3.optimal_gain_over.{name}", f"{gap:.4f}", "J units")


if __name__ == "__main__":
    main()
