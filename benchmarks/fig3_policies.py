"""Paper Fig 3: J under uniform allocations {0,100,500} vs the optimal
heterogeneous l*, analytically AND through the DES (10k queries)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import objective, paper_problem, solve
from repro.queueing_sim import generate_stream, simulate

from .common import emit


def main() -> None:
    prob = paper_problem()
    sol = solve(prob)
    stream = generate_stream(prob.tasks, prob.server.lam, 10_000, seed=0)

    policies = {
        "uniform_0": np.zeros(6),
        "uniform_100": np.full(6, 100.0),
        "uniform_500": np.full(6, 500.0),
        "optimal": np.asarray(sol.lengths_int),
    }
    j_opt = None
    for name, l in policies.items():
        j_analytic = float(objective(prob, jnp.asarray(l)))
        res = simulate(prob, l, stream)
        emit(f"fig3.J_analytic.{name}", f"{j_analytic:.4f}", "")
        emit(f"fig3.J_des.{name}", f"{res.objective:.4f}",
             f"mean_sys={res.mean_system_time:.3f}")
        if name == "optimal":
            j_opt = j_analytic
    for name, l in policies.items():
        if name != "optimal":
            gap = j_opt - float(objective(prob, jnp.asarray(l)))
            emit(f"fig3.optimal_gain_over.{name}", f"{gap:.4f}", "J units")


if __name__ == "__main__":
    main()
