"""Paper Fig 3: J under uniform allocations {0,100,500} vs the optimal
heterogeneous l*, analytically AND through the DES.

Runs on the batched Lindley path: all four policies x 8 seeds x 10k queries
are a single vectorized call (the legacy heapq loop simulated one policy per
Python call), so the DES column now carries a 95% CI for free.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import objective, paper_problem, solve
from repro.queueing_sim import sweep

from .common import emit


def main() -> None:
    prob = paper_problem()
    sol = solve(prob)

    policies = {
        "uniform_0": np.zeros(6),
        "uniform_100": np.full(6, 100.0),
        "uniform_500": np.full(6, 500.0),
        "optimal": np.asarray(sol.lengths_int),
    }
    res = sweep(prob, policies, lams=[prob.server.lam], n_seeds=8,
                n_queries=10_000, seed=0)
    j_opt = None
    for p, name in enumerate(res.policy_names):
        j_analytic = float(objective(prob, jnp.asarray(policies[name])))
        emit(f"fig3.J_analytic.{name}", f"{j_analytic:.4f}", "")
        emit(f"fig3.J_des.{name}", f"{res.objective[0, p]:.4f}",
             f"+-{res.ci_objective[0, p]:.4f}, "
             f"mean_sys={res.mean_system_time[0, p]:.3f}")
        if name == "optimal":
            j_opt = j_analytic
    for name in res.policy_names:
        if name != "optimal":
            gap = j_opt - float(objective(prob, jnp.asarray(policies[name])))
            emit(f"fig3.optimal_gain_over.{name}", f"{gap:.4f}", "J units")


if __name__ == "__main__":
    main()
